"""Pass 1 (jaxpr verifier) unit tests: taint propagation per invariant,
control-flow recursion, cache contract, site checks, and the real-engine
sweeps that CI runs."""

import os

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis import verifier
from repro.analysis.selftest import load_fixture_module
from repro.core import packing

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(REPO, "analysis", "fixtures")


def _rules(findings):
    return {f.rule for f in findings}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@pytest.fixture(scope="module")
def bad_kernel():
    return load_fixture_module(os.path.join(FIXTURES, "bad_kernel.py"))


# ---------------------------------------------------------------------------
# taint walker on the seeded bad fixtures
# ---------------------------------------------------------------------------


def test_packed_to_float_flagged(bad_kernel):
    found = verifier.check_function(
        bad_kernel.leak_packed_to_float, _sds((8, 2), jnp.uint32)
    )
    assert "INV-PACKED-FLOAT" in _rules(found)


def test_bf16_accumulation_flagged(bad_kernel):
    found = verifier.check_function(
        bad_kernel.accumulate_in_bf16,
        _sds((8, 2), jnp.uint32),
        _sds((8, 2), jnp.uint32),
    )
    assert "INV-ACCUM-LOWFP" in _rules(found)


def test_pallas_kernel_lowfp_output_flagged(bad_kernel):
    """The kernel-boundary arm of INV-ACCUM-LOWFP: a pallas_call fed packed
    planes may exit int (counts) or f32 (fused epilogue) — never bf16."""
    found = verifier.check_function(
        bad_kernel.fused_kernel_lowfp,
        _sds((8, 2), jnp.uint32),
        _sds((8, 2), jnp.uint32),
    )
    assert "INV-ACCUM-LOWFP" in _rules(found)


def test_low_precision_int_dot_flagged(bad_kernel):
    found = verifier.check_function(
        bad_kernel.int_dot_low_precision,
        _sds((4, 8), jnp.int8),
        _sds((8, 4), jnp.int8),
    )
    assert "INV-INT-DOT" in _rules(found)


# ---------------------------------------------------------------------------
# taint walker on clean idioms (no false positives)
# ---------------------------------------------------------------------------


def test_popcount_then_f32_epilogue_clean():
    # the legal datapath: AND -> popcount -> int32 sum -> f32 epilogue
    def good(a, b):
        counts = lax.population_count(a & b)
        acc = jnp.sum(counts.astype(jnp.int32), axis=-1)
        return acc.astype(jnp.float32) * 0.5

    found = verifier.check_function(
        good, _sds((8, 2), jnp.uint32), _sds((8, 2), jnp.uint32)
    )
    assert found == []


def test_unpack_launders_packed_taint():
    def good(p):
        x = packing.unpack_bits(p, 1, 64, axis=0, dtype=jnp.int32)
        return x.astype(jnp.float32)

    found = verifier.check_function(good, _sds((2, 16), jnp.uint32))
    assert found == []


def test_pack_output_is_tainted():
    def bad(x):
        p = packing.pack_bits(x, 1, axis=-1)
        return p.astype(jnp.float32)  # packed words treated as numbers

    found = verifier.check_function(bad, _sds((4, 64), jnp.uint8))
    assert "INV-PACKED-FLOAT" in _rules(found)


def test_int32_dot_with_preferred_type_clean():
    def good(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.int32)

    found = verifier.check_function(
        good, _sds((4, 8), jnp.int8), _sds((8, 4), jnp.int8)
    )
    assert found == []


def test_taint_flows_through_scan():
    # packed carry survives a scan and leaks to float afterwards
    def bad(p):
        def body(c, _):
            return c & jnp.uint32(7), c

        _, ys = lax.scan(body, p, None, length=3)
        return ys.astype(jnp.float32)

    found = verifier.check_function(bad, _sds((8,), jnp.uint32))
    assert "INV-PACKED-FLOAT" in _rules(found)


def test_bool_outputs_drop_taint():
    # comparisons on packed words produce masks, not numbers — selecting
    # floats under such a mask is fine
    def good(p, x):
        mask = (p & jnp.uint32(1)) > 0
        return jnp.where(mask, x, 0.0)

    found = verifier.check_function(
        good, _sds((8,), jnp.uint32), _sds((8,), jnp.float32)
    )
    assert found == []


# ---------------------------------------------------------------------------
# cache contract
# ---------------------------------------------------------------------------


def test_cache_dtype_drift_caught(bad_kernel):
    found = verifier.check_cache_contract(
        lambda: bad_kernel.init_cache(2, 8, 4),
        bad_kernel.drifting_step,
        _sds((2, 4), jnp.float32),
    )
    assert _rules(found) == {"INV-CACHE-DTYPE"}
    assert "conv" not in found[0].symbol  # leaf path names the drifted slot
    assert "'k'" in found[0].symbol


def test_cache_shape_growth_caught(bad_kernel):
    found = verifier.check_cache_contract(
        lambda: bad_kernel.init_cache(2, 8, 4),
        bad_kernel.growing_step,
        _sds((2, 4), jnp.float32),
    )
    assert "INV-CACHE-SHAPE" in _rules(found)


def test_cache_struct_change_caught(bad_kernel):
    found = verifier.check_cache_contract(
        lambda: bad_kernel.init_cache(2, 8, 4),
        lambda cache, x: {"k": cache["k"]},  # drops the pos leaf
        _sds((2, 4), jnp.float32),
    )
    assert _rules(found) == {"INV-CACHE-STRUCT"}


def test_pr6_drift_reintroduction_caught():
    """Reintroducing the PR 6 bug (an SSM conv window written in bf16 into
    an f32-initialized slot) in a real model step must be flagged."""
    from repro.configs import get_config
    from repro.configs.smoke import smoke_variant
    from repro.models import model_zoo as Z

    cfg = smoke_variant(get_config("mamba2-130m"))
    sp = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        _sds((2,), jnp.uint32),
    )
    tok = _sds((2,), jnp.int32)

    def drifted_decode(cache, tokens, params):
        _, c = Z.decode_step(params, tokens, cfg, cache)
        per0 = dict(c["stack"]["period"][0])
        per0["conv"] = per0["conv"].astype(jnp.bfloat16)  # the bug
        stack = dict(c["stack"], period=[per0] + list(c["stack"]["period"][1:]))
        return dict(c, stack=stack)

    def clean_decode(cache, tokens, params):
        return Z.decode_step(params, tokens, cfg, cache)[1]

    init = lambda: Z.init_cache(2, 32, cfg)
    assert verifier.check_cache_contract(init, clean_decode, tok, sp) == []
    found = verifier.check_cache_contract(init, drifted_decode, tok, sp)
    assert "INV-CACHE-DTYPE" in _rules(found)
    assert any("conv" in f.symbol for f in found)


# ---------------------------------------------------------------------------
# site checks
# ---------------------------------------------------------------------------


def _cfg(name="granite-8b"):
    from repro.configs import get_config
    from repro.configs.smoke import smoke_variant

    return smoke_variant(get_config(name))


def test_site_findings_unnamed_and_bits_and_mantissa():
    cfg = _cfg()
    sites = [
        {"kind": "qlinear", "site": "", "bits": 8, "cfg_bits": 8,
         "mantissa_dtype": "uint8"},
        {"kind": "qlinear", "site": "ffn.up", "bits": 4, "cfg_bits": 8,
         "mantissa_dtype": "uint8"},
        {"kind": "qlinear", "site": "ffn.down", "bits": 8, "cfg_bits": 8,
         "mantissa_dtype": "int32"},
        {"kind": "attn", "site": "attn.qk", "bits": cfg.quant.attn_act_bits,
         "mantissa_dtype": "int8"},
    ]
    found = verifier._site_findings(sites, cfg, "t")
    assert _rules(found) == {"INV-SITE-NAME", "INV-SITE-BITS", "INV-SITE-MANTISSA"}


def test_arch_trace_records_named_sites():
    from repro.core import site_log
    from repro.models import model_zoo as Z

    cfg = _cfg()
    sp = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        _sds((2,), jnp.uint32),
    )
    cache = jax.eval_shape(lambda: Z.init_cache(2, 32, cfg))
    with site_log.recording() as sites:
        jax.eval_shape(
            lambda p, t, c: Z.decode_step(p, t, cfg, c),
            sp, _sds((2,), jnp.int32), cache,
        )
    ql = [s for s in sites if s["kind"] == "qlinear"]
    assert ql, "decode trace recorded no qlinear sites"
    assert all(s["site"] for s in ql)
    assert {"attn"} <= {s["kind"] for s in sites}  # act x act sites too


# ---------------------------------------------------------------------------
# the real-engine sweeps CI runs (one backend + one arch here; CI runs all)
# ---------------------------------------------------------------------------


def _registered_backends():
    from repro.core import backend_registry

    # qmm family only: scores-family backends have a different calling
    # convention and are swept by verify_binary_attention instead.
    return backend_registry.backend_names(family="qmm")


@pytest.mark.parametrize("backend", _registered_backends())
def test_backend_sweep_clean(backend):
    """Every *registered* backend traces clean — enumerated from the
    registry, so a new backend joins this sweep with zero test edits.
    For "fused" this is the acceptance check that the packed planes flowing
    into the pallas_call and the f32 epilogue exit satisfy the taint rules
    (INV-PACKED-FLOAT, INV-ACCUM-LOWFP)."""
    from repro.analysis.findings import render_text

    found = verifier.verify_backends((backend,))
    assert found == [], render_text(found)


def test_arch_sweep_clean_one_arch():
    from repro.analysis.findings import render_text

    found = verifier.verify_arch("mamba2-130m")
    assert found == [], render_text(found)


def test_encoder_only_arch_skipped():
    assert verifier.verify_arch("bit-bert-base") == []
