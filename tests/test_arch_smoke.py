"""Per-architecture smoke tests (assignment requirement).

For each assigned arch: instantiate the REDUCED config of the same family
(configs/smoke.py), run one forward/train step and one prefill+decode step
on CPU, assert output shapes and no NaNs.  The FULL configs are exercised
via the dry-run only.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z

ALL = ASSIGNED + ("bit-bert-base",)


def _batch(cfg, b=2, s=16):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.encoder is not None:
        d_in = cfg.encoder.d_input or cfg.d_model
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder.n_positions, d_in), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def built():
    """Init each smoke model once per test session."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_variant(get_config(name))
            params = Z.init_params(jax.random.PRNGKey(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ALL)
def test_train_step_shapes_and_finite(built, name):
    cfg, params = built(name)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: Z.loss_fn(p, batch, cfg, "train"), has_aux=True
    )(params)
    assert jnp.isfinite(loss), f"{name}: non-finite loss"
    # gradients exist and are finite for latent weights
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), f"{name}: NaN grads"


@pytest.mark.parametrize("name", ALL)
def test_forward_logits_shape(built, name):
    cfg, params = built(name)
    batch = _batch(cfg)
    logits, aux = Z.forward_logits(
        params, batch["tokens"], cfg, "train", batch.get("frontend")
    )
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL)
def test_serve_prefill_decode(built, name):
    cfg, params = built(name)
    if not cfg.has_decoder and cfg.family == "encoder":
        pytest.skip("encoder-only: no decode step (assignment rule)")
    batch = _batch(cfg)
    sp = Z.prepare_serving_params(params, cfg)
    cache = Z.init_cache(2, 32, cfg)
    logits, cache = Z.prefill(sp, batch["tokens"], cfg, cache, batch.get("frontend"))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = Z.decode_step(sp, nxt, cfg, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ["granite-8b", "qwen3-32b", "mamba2-130m"])
def test_serve_decode_matches_full_forward(built, name):
    """Decode-with-cache must agree with full-sequence forward (float mode,
    no quantization noise): the cache machinery itself is exact."""
    import dataclasses

    from repro.configs.base import FLOAT_QUANT

    cfg, _ = built(name)
    cfg = dataclasses.replace(cfg, quant=FLOAT_QUANT, name=cfg.name + "-fp")
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = Z.forward_logits(params, tokens, cfg, "float")
    cache = Z.init_cache(1, 16, cfg)
    _, cache = Z.prefill(params, tokens[:, :-1], cfg, cache)
    step_logits, _ = Z.decode_step(params, tokens[:, -1], cfg, cache)
    import numpy as np

    np.testing.assert_allclose(
        np.asarray(step_logits[0]),
        np.asarray(full_logits[0, -1]),
        rtol=2e-2,
        atol=2e-2,
    )


@pytest.mark.parametrize("name", ["granite-8b"])
def test_quantized_serve_close_to_float(built, name):
    """W1A8 serving must track the QAT (fake-quant) forward: same weights,
    integer vs float datapath."""
    cfg, params = built(name)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab_size)
    train_logits, _ = Z.forward_logits(params, tokens, cfg, "train")
    sp = Z.prepare_serving_params(params, cfg)
    cache = Z.init_cache(1, 16, cfg)
    serve_logits, _ = Z.prefill(sp, tokens, cfg, cache)
    t = jnp.argsort(train_logits[0, -1])[-5:]
    s = jnp.argsort(serve_logits[0])[-5:]
    # datapaths differ in quantizer granularity; demand ranking overlap
    overlap = len(set(map(int, t)) & set(map(int, s)))
    assert overlap >= 2, f"serve/train top-5 overlap too low: {overlap}"
