"""Property-based slot-management invariants (hypothesis, optional dep).

Three serving invariants the continuous-batching engine must hold for ANY
request mix:

1. slot exclusivity — a decode slot never serves two requests at once
   (checked on the engine's event trace: admit/reset intervals per slot
   are disjoint);
2. completion — every admitted request finishes with exactly its
   ``max_new_tokens`` tokens (no slot starvation, no over-generation);
3. pad isolation — padding never leaks into outputs: the engine prefills
   at exact prompt length, and the bucketed right-pad path
   (``model_zoo.prefill(length=...)``) must produce the same last-token
   logits as the unpadded prompt no matter what garbage sits in the pad
   region.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional test dep; gate, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import FLOAT_QUANT
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine

MAX_LEN = 48


@pytest.fixture(scope="module")
def built():
    cfg = smoke_variant(get_config("granite-8b"))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    return cfg, serving


request_sets = st.lists(
    st.tuples(
        st.integers(min_value=1, max_value=10),  # prompt_len
        st.integers(min_value=1, max_value=6),  # max_new_tokens
    ),
    min_size=1,
    max_size=6,
)


def _slot_intervals(events):
    """Per-slot [admit, reset) request intervals from the event trace."""
    spans = {}
    open_ = {}
    for e in events:
        if e["kind"] == "admit":
            assert e["slot"] not in open_, "slot admitted while occupied"
            open_[e["slot"]] = e
        elif e["kind"] == "reset":
            a = open_.pop(e["slot"])
            assert a["rid"] == e["rid"], "slot freed for a different request"
            spans.setdefault(e["slot"], []).append((a["rid"], a["t"], e["t"]))
    assert not open_, "slot never freed"
    return spans


@settings(max_examples=8, deadline=None)
@given(shape=request_sets, seed=st.integers(min_value=0, max_value=2**16))
def test_slot_exclusivity_and_exact_completion(built, shape, seed):
    cfg, serving = built
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(plen,)).astype(np.int32),
            max_new_tokens=nnew,
        )
        for plen, nnew in shape
    ]
    eng = ServeEngine(cfg, serving, batch_slots=2, max_len=MAX_LEN, seed=seed)
    done = eng.run(reqs)

    # completion: every request, exactly max_new_tokens, in submission order
    assert len(done) == len(reqs)
    for r in done:
        assert len(r.output) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in r.output)

    # exclusivity: per-slot occupancy intervals never overlap
    for slot, spans in _slot_intervals(eng.last_events).items():
        spans = sorted(spans, key=lambda s: s[1])
        for (_, _, end_prev), (_, start_next, _) in zip(spans, spans[1:]):
            assert end_prev <= start_next, f"slot {slot} double-booked"

    # every decode tick serves at most one request per slot by construction;
    # check the trace agrees with the admit/reset intervals
    for e in eng.last_events:
        if e["kind"] != "decode_tick":
            continue
        rids = [r for r in e["rids"] if r is not None]
        assert len(rids) == len(set(rids)), "one request in two slots"


@settings(max_examples=8, deadline=None)
@given(
    plen=st.integers(min_value=1, max_value=10),
    pad=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_right_padding_never_leaks_into_logits(built, plen, pad, seed):
    """Bucketed prefill (float cache): garbage in the pad region must not
    change the last-real-token logits nor the cache the request decodes
    from (pads sit at causally-later positions; cursors rewind to length)."""
    cfg, _ = built
    cfg = dataclasses.replace(cfg, quant=FLOAT_QUANT, name=cfg.name + "-fp")
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, size=(1, plen)).astype(np.int32)
    garbage = rng.integers(0, cfg.vocab_size, size=(1, pad)).astype(np.int32)
    padded = np.concatenate([prompt, garbage], axis=1)

    exact_logits, exact_cache = Z.prefill(
        params, jnp.asarray(prompt), cfg, Z.init_cache(1, MAX_LEN, cfg)
    )
    pad_logits, pad_cache = Z.prefill(
        params,
        jnp.asarray(padded),
        cfg,
        Z.init_cache(1, MAX_LEN, cfg),
        length=jnp.asarray([plen]),
    )
    np.testing.assert_allclose(
        np.asarray(pad_logits), np.asarray(exact_logits), rtol=1e-4, atol=1e-4
    )
    # one greedy decode step from each cache agrees too
    nxt = jnp.argmax(exact_logits, -1).astype(jnp.int32)
    d_exact, _ = Z.decode_step(params, nxt, cfg, exact_cache)
    d_pad, _ = Z.decode_step(params, nxt, cfg, pad_cache)
    np.testing.assert_allclose(
        np.asarray(d_pad), np.asarray(d_exact), rtol=1e-4, atol=1e-4
    )
