"""Property tests: the computation-flow abstraction is EXACT.

The paper's claim (§III-A): reordering ``(aA + g1)(bW + g2)`` into an integer
MM plus quadratic corrections changes nothing about the result.  We assert
equality against the dequantize-then-matmul oracle to fp32 rounding, across
both QMM types, every engine precision mode, and every integer backend.
"""

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional test dep; gate, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core import flow_abstraction as FA
from repro.core import qmm as QE
from repro.core import quantization as Q


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


def _tol(ref):
    return 2e-5 * max(1.0, float(jnp.max(jnp.abs(ref))))


@pytest.mark.parametrize("act_bits", [1, 2, 4, 8])
@pytest.mark.parametrize("backend", ["mxu", "popcount"])
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_act_weight_equals_oracle(act_bits, backend, seed):
    rng = np.random.default_rng(seed)
    m, k, n = rng.integers(1, 40), int(rng.integers(1, 130)), int(rng.integers(1, 40))
    x = _rand(rng, m, k)
    w = _rand(rng, k, n)
    xq = Q.quantize_activation(x, act_bits)
    wq = Q.binarize_weight(w)
    ref = FA.qmm_dequant_reference(xq, wq)
    out = QE.qmm(xq, wq, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=_tol(ref))


@pytest.mark.parametrize("act_bits", [1, 2, 4, 8])
@pytest.mark.parametrize("backend", ["mxu", "popcount"])
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_act_act_equals_oracle(act_bits, backend, seed):
    """QMM type 2 — the capability prior accelerators lack (paper §II)."""
    rng = np.random.default_rng(seed)
    b, m, k, n = 2, int(rng.integers(1, 20)), int(rng.integers(1, 70)), int(rng.integers(1, 20))
    a = _rand(rng, b, m, k)
    v = _rand(rng, b, k, n)
    aq = Q.quantize_activation(a, act_bits)
    vq = Q.quantize_activation(v, act_bits)
    ref = FA.qmm_dequant_reference(aq, vq)
    out = QE.qmm(aq, vq, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=_tol(ref))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_integer_core_is_exact(seed):
    """The cubic term is pure integer math — bit-exact across backends."""
    rng = np.random.default_rng(seed)
    m, k, n = 8, 96, 8
    x = rng.integers(0, 2, size=(m, k)).astype(np.int32)
    w = rng.integers(0, 2, size=(k, n)).astype(np.int32)
    ref = x @ w
    mxu = FA.default_int_matmul(jnp.asarray(x), jnp.asarray(w), 1, 1)
    pop = QE.popcount_int_matmul(jnp.asarray(x), jnp.asarray(w), 1, 1)
    np.testing.assert_array_equal(np.asarray(mxu), ref)
    np.testing.assert_array_equal(np.asarray(pop), ref)


@pytest.mark.parametrize("bits", [(1, 1), (4, 1), (8, 8), (4, 4), (2, 8)])
def test_bitserial_popcount_exact(bits):
    xb, yb = bits
    rng = np.random.default_rng(42)
    x = rng.integers(0, 2**xb, size=(7, 65)).astype(np.int32)
    y = rng.integers(0, 2**yb, size=(65, 9)).astype(np.int32)
    out = QE.popcount_int_matmul(jnp.asarray(x), jnp.asarray(y), xb, yb)
    np.testing.assert_array_equal(np.asarray(out), x @ y)


def test_recenter_is_exact():
    rng = np.random.default_rng(1)
    for bits in (2, 4, 8):
        x = _rand(rng, 6, 33)
        q = Q.quantize_activation(x, bits)
        rq = Q.recenter(q)
        assert rq.mantissa.dtype == jnp.int8
        np.testing.assert_allclose(
            np.asarray(rq.dequantize()), np.asarray(q.dequantize()), rtol=1e-6, atol=1e-6
        )


def test_weight_colsum_precompute_matches_inline():
    rng = np.random.default_rng(2)
    x = _rand(rng, 5, 64)
    w = _rand(rng, 64, 10)
    xq = Q.quantize_activation(x, 4)
    wq = Q.binarize_weight(w)
    a = QE.qmm(xq, wq, backend="mxu")
    b = QE.qmm(xq, wq, backend="mxu", w_colsum=FA.weight_corrections(wq))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_packed_operands_accepted():
    """Serving path: weights arrive bit-packed from the checkpoint."""
    rng = np.random.default_rng(3)
    x = _rand(rng, 5, 64)
    w = _rand(rng, 64, 10)
    xq = Q.quantize_activation(x, 1)
    wq = Q.binarize_weight(w).pack(axis=0)
    assert wq.packed and wq.mantissa.dtype == jnp.uint32
    assert wq.logical_shape == (64, 10)
    ref = QE.qmm(xq, Q.binarize_weight(w), backend="mxu")
    out = QE.qmm(xq, wq, backend="mxu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


def test_op_count_accounting_matches_fig2():
    """Fig. 2: N^3 Op -> 2N^3 Iop + (3N^2+2) Op for square act x weight."""
    n = 64
    naive = FA.op_counts_naive(n, n, n)
    assert naive == {"fp_ops": 2 * n**3, "int_ops": 0}
    abst = FA.op_counts_abstracted(n, n, n, weight_static=True)
    assert abst["fp_ops"] == 3 * n**2 + 2
    assert abst["int_ops"] == 2 * n**3 + n * n  # integer MM + rowsum


def test_chunked_accumulation_large_k():
    """8-bit x 8-bit with K big enough to trigger chunking stays correct."""
    rng = np.random.default_rng(4)
    k = 40000  # 2^14 * 128*128 > 2^30 -> chunked
    x = rng.integers(-128, 128, size=(2, k)).astype(np.int32)
    y = rng.integers(-128, 128, size=(k, 3)).astype(np.int32)
    out = FA.default_int_matmul(jnp.asarray(x), jnp.asarray(y), 8, 8)
    ref = (x.astype(np.int64) @ y.astype(np.int64)).astype(np.float64)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float64), ref, rtol=1e-6)
