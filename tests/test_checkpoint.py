"""Checkpoint manager: atomicity, keep-k, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    m.save(5, tree, extras={"pipeline": {"cursor": 42, "seed": 0}})
    step, out, extras = m.restore(like=jax.tree.map(jnp.zeros_like, tree))
    assert step == 5
    assert extras["pipeline"]["cursor"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_prunes_old(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_latest_ignores_uncommitted(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _tree())
    # fake a torn write: directory without _COMMITTED
    os.makedirs(tmp_path / "step_000000002")
    assert m.latest_step() == 1


def test_restore_rejects_shape_mismatch(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=1)
    m.save(1, _tree())
    bad = {"a": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros(10, jnp.int32)}}
    with pytest.raises(ValueError):
        m.restore(like=bad)


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save unsharded, restore with explicit shardings on a 2-device mesh —
    the elastic-rescale path (CPU: single device behaves as a 1x1 mesh; the
    multi-device variant runs in test_fault_tolerance via subprocess)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    m = CheckpointManager(str(tmp_path), keep=1)
    tree = _tree()
    m.save(1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {
        "a": NamedSharding(mesh, P(None, None)),
        "nested": {"b": NamedSharding(mesh, P())},
    }
    _, out, _ = m.restore(like=jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
