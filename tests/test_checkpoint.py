"""Checkpoint manager: atomicity, keep-k, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    m.save(5, tree, extras={"pipeline": {"cursor": 42, "seed": 0}})
    step, out, extras = m.restore(like=jax.tree.map(jnp.zeros_like, tree))
    assert step == 5
    assert extras["pipeline"]["cursor"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_prunes_old(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_latest_ignores_uncommitted(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _tree())
    # fake a torn write: directory without _COMMITTED
    os.makedirs(tmp_path / "step_000000002")
    assert m.latest_step() == 1


def test_restore_rejects_shape_mismatch(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=1)
    m.save(1, _tree())
    bad = {"a": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros(10, jnp.int32)}}
    with pytest.raises(ValueError):
        m.restore(like=bad)


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save unsharded, restore with explicit shardings on a 2-device mesh —
    the elastic-rescale path (CPU: single device behaves as a 1x1 mesh; the
    multi-device variant runs in test_fault_tolerance via subprocess)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    m = CheckpointManager(str(tmp_path), keep=1)
    tree = _tree()
    m.save(1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {
        "a": NamedSharding(mesh, P(None, None)),
        "nested": {"b": NamedSharding(mesh, P())},
    }
    _, out, _ = m.restore(like=jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


# ---------------------------------------------------------------------------
# overwrite atomicity: the rename-aside window (serving snapshots overwrite
# the same step every boundary, so this path is hot)
# ---------------------------------------------------------------------------


def test_overwrite_replaces_content_without_residue(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _tree(1), extras={"v": 1})
    m.save(1, _tree(2), extras={"v": 2})
    step, out, extras = m.restore(like=jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 1 and extras["v"] == 2
    for a, b in zip(jax.tree.leaves(_tree(2)), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    leftovers = [n for n in os.listdir(tmp_path) if ".old-" in n or ".tmp-" in n]
    assert not leftovers, leftovers


def test_overwrite_crash_between_renames_restores_old_step(tmp_path, monkeypatch):
    """Fail the tmp->final rename of an overwrite: the previously committed
    step must still be restorable (the old dir was renamed ASIDE, never
    deleted, and the failure path renames it back)."""
    import repro.checkpoint.manager as CM

    m = CheckpointManager(str(tmp_path), keep=3)
    t1 = _tree(1)
    m.save(7, t1, extras={"v": 1})

    real_rename = os.rename

    def failing_rename(src, dst):
        # let the aside rename (dst = step_X.old-*) through; crash only on
        # the commit rename (dst = the final step dir)
        if os.path.basename(dst) == "step_000000007":
            raise OSError("injected crash between renames")
        return real_rename(src, dst)

    monkeypatch.setattr(CM.os, "rename", failing_rename)
    with pytest.raises(OSError, match="injected crash"):
        m.save(7, _tree(2), extras={"v": 2})
    monkeypatch.undo()

    m2 = CheckpointManager(str(tmp_path), keep=3)
    step, out, extras = m2.restore(like=jax.tree.map(jnp.zeros_like, t1))
    assert step == 7 and extras["v"] == 1
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not [n for n in os.listdir(tmp_path) if ".old-" in n]


def test_recovery_renames_stranded_aside_back(tmp_path):
    """Simulate a hard crash (no in-process handler) between the two renames:
    only ``step_X.old-<nonce>`` exists on disk.  A new manager's recovery
    pass renames it back into place."""
    import shutil

    m = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(3)
    m.save(2, t, extras={"v": 3})
    final = os.path.join(str(tmp_path), "step_000000002")
    os.rename(final, final + ".old-deadbeef")
    # plus an uncommitted husk of the new write that never finished
    os.makedirs(final)
    with open(os.path.join(final, "arrays.npz"), "wb") as f:
        f.write(b"torn")

    m2 = CheckpointManager(str(tmp_path), keep=3)
    assert m2.latest_step() == 2
    step, out, extras = m2.restore(like=jax.tree.map(jnp.zeros_like, t))
    assert extras["v"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not [n for n in os.listdir(tmp_path) if ".old-" in n]

    # inverse crash point: commit landed, aside removal didn't -> recovery
    # deletes the stale aside and keeps the committed final
    shutil.copytree(final, final + ".old-cafe0000")
    m3 = CheckpointManager(str(tmp_path), keep=3)
    assert m3.latest_step() == 2
    assert not [n for n in os.listdir(tmp_path) if ".old-" in n]


def test_exotic_dtype_leaves_roundtrip_exact_bits(tmp_path):
    """bfloat16 (and other ml_dtypes) leaves must survive npz bit-exactly —
    np.savez would silently degrade them to void bytes.  Serve-cache
    snapshots are full of bf16 KV rows, so this is load-bearing for
    crash recovery."""
    bf16 = (jnp.arange(-8, 8, dtype=jnp.float32) / 3.0).astype(jnp.bfloat16)
    tree = {
        "kv": bf16.reshape(4, 4),
        "q": jnp.arange(-8, 8, dtype=jnp.int8),
        "pos": jnp.arange(4, dtype=jnp.int32),
    }
    m = CheckpointManager(str(tmp_path), keep=1)
    m.save(1, tree)
    _, out, _ = m.restore(like=jax.tree.map(jnp.zeros_like, tree))
    assert out["kv"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["kv"]).view(np.uint16),
        np.asarray(tree["kv"]).view(np.uint16),
    )
    np.testing.assert_array_equal(np.asarray(out["q"]), np.asarray(tree["q"]))
