"""Fault tolerance end-to-end: preemption (SIGTERM) -> restart -> bit-exact
resume; elastic mesh rescale via checkpoint; compressed-DP parity.

The preemption test runs a REAL training subprocess, kills it mid-run, and
verifies the relaunched run continues from the checkpoint with the exact
data cursor.
"""

import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.smoke import smoke_variant
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.runtime import fault_tolerance as FT
from repro.runtime import train_loop as TL


def _mini_setup(tmp_path, steps=10, ckpt_every=4, schedule_steps=10):
    """``steps`` is where the RUN stops; ``schedule_steps`` is the optimizer
    horizon — kept separate so a preempted run and its resume share the
    exact LR trajectory (as a real deployment would)."""
    cfg = smoke_variant(get_config("bit-bert-base"))
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )
    tcfg = TL.TrainConfig(
        optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=schedule_steps)
    )
    shapes = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    step = TL.make_train_step(cfg, tcfg, mesh, shapes)
    pipe = TokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=3)
    )
    mgr = CheckpointManager(str(tmp_path), keep=2)
    runner = FT.TrainingRunner(
        step, pipe, mgr,
        FT.RunnerConfig(total_steps=steps, checkpoint_every=ckpt_every, log_every=100),
        log_fn=lambda *_: None,
    )
    params, opt = TL.init_train_state(jax.random.PRNGKey(0), cfg)
    return cfg, runner, params, opt, mgr, pipe


def test_resume_is_bit_exact(tmp_path):
    """Train 10 straight vs train 4 + checkpoint + resume 6: identical."""
    # run A: straight through
    _, runner, params, opt, _, _ = _mini_setup(tmp_path / "a", steps=10)
    pa, oa, _ = runner.run(params, opt)

    # run B: stop after 4 (checkpoint), rebuild everything, resume
    _, runner1, params, opt, mgr, _ = _mini_setup(tmp_path / "b", steps=4)
    pb, ob, _ = runner1.run(params, opt)
    _, runner2, params2, opt2, mgr2, _ = _mini_setup(tmp_path / "b", steps=10)
    start, pr, orr = runner2.try_restore(params2, opt2)
    assert start == 4
    pb2, ob2, _ = runner2.run(pr, orr, start)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_sigterm_preemption_subprocess(tmp_path):
    """Kill a real training run mid-flight; verify clean checkpoint+resume."""
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "bit-bert-base", "--smoke",
        "--steps", "400", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
    ]
    proc = subprocess.Popen(
        cmd, env=env, cwd=os.getcwd(),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    time.sleep(75)  # let it compile + take some steps
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=300)
    assert "preemption" in out or proc.returncode == 0, out[-2000:]

    mgr = CheckpointManager(str(tmp_path))
    step = mgr.latest_step()
    assert step is not None and step > 0, out[-2000:]

    # resume: must pick up from the checkpoint, not step 0
    out2 = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "bit-bert-base", "--smoke",
            "--steps", str(step + 3), "--batch", "4", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "100",
        ],
        env=env, cwd=os.getcwd(), capture_output=True, text=True, timeout=300,
    )
    assert f"resumed from step {step}" in out2.stdout, out2.stdout[-2000:]


def test_elastic_rescale_via_checkpoint(tmp_path):
    """Save from a 1-shard run, restore into a 2-shard pipeline + params —
    the lose-a-pod / add-a-pod path."""
    cfg, runner, params, opt, mgr, pipe = _mini_setup(tmp_path, steps=4)
    p1, o1, _ = runner.run(params, opt)

    # 'new job' with 2 shards per... restore global state
    new_pipe = pipe.reshard(shard_index=1, num_shards=2)
    assert new_pipe.cursor == pipe.cursor
    step, tree, extras = mgr.restore(like={"params": p1, "opt": o1})
    assert step == 4 and extras["pipeline"]["cursor"] == pipe.cursor


def test_straggler_metrics_exposed(tmp_path):
    _, runner, params, opt, _, _ = _mini_setup(tmp_path, steps=6)
    runner.run(params, opt)
    assert runner.p50 > 0 and runner.p99 >= runner.p50


def test_signal_handlers_chain_and_restore(tmp_path):
    """install_signal_handlers must save, CHAIN, and restore whatever the
    host process had installed — a runner that clobbers an orchestrator's
    drain handler (or pytest's SIGINT machinery) breaks the host."""
    _, runner, *_ = _mini_setup(tmp_path, steps=2)

    chained = []

    def host_handler(signum, frame):
        chained.append(signum)

    original = signal.signal(signal.SIGTERM, host_handler)
    try:
        runner.install_signal_handlers()
        assert signal.getsignal(signal.SIGTERM) is not host_handler
        # a second install must not clobber the SAVED originals with the
        # runner's own handler (idempotence)
        runner_handler = signal.getsignal(signal.SIGTERM)
        runner.install_signal_handlers()
        assert signal.getsignal(signal.SIGTERM) is runner_handler

        signal.raise_signal(signal.SIGTERM)
        assert runner._preempted  # the runner saw it...
        assert chained == [signal.SIGTERM]  # ...and the host handler ran too

        runner.restore_signal_handlers()
        assert signal.getsignal(signal.SIGTERM) is host_handler
        # restore is a reset: a later install re-saves the CURRENT handlers
        chained.clear()
        signal.raise_signal(signal.SIGTERM)
        assert chained == [signal.SIGTERM]
    finally:
        signal.signal(signal.SIGTERM, original)
