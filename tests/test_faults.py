"""Fault-plan/injector units (host-only, no model compiles)."""

import json

import pytest

from repro.runtime.faults import (
    BackendFault,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    parse_fault_plan,
)


def test_default_plan_is_noop():
    plan = FaultPlan()
    assert plan.is_noop()
    inj = FaultInjector(plan)
    for t in range(10):
        inj.before_decode(t)
    inj.before_prefill(0)
    inj.on_snapshot(0)
    assert inj.injected == 0


def test_parse_round_trip():
    plan = FaultPlan(
        decode_fail_ticks=(1, 3),
        backend_fail={"fused": 2},
        nan_ticks={2: 1},
        delay_ticks={4: 0.25},
        prefill_fail_rids={7: 1},
        snapshot_fail_at=(0,),
    )
    assert not plan.is_noop()
    # to_dict -> JSON -> parse is identity (CLI --fault-plan path)
    again = parse_fault_plan(json.dumps(plan.to_dict()))
    assert again == plan


def test_parse_accepts_none_plan_and_dict():
    assert parse_fault_plan(None) == FaultPlan()
    plan = FaultPlan(decode_fail_ticks=(5,))
    assert parse_fault_plan(plan) is plan
    assert parse_fault_plan({"decode_fail_ticks": [5]}) == plan


def test_parse_rejects_unknown_keys_and_non_objects():
    with pytest.raises(ValueError, match="unknown fault plan keys"):
        parse_fault_plan({"decode_fail_tickz": [1]})
    with pytest.raises(ValueError, match="JSON object"):
        parse_fault_plan("[1, 2]")
    # mapping-valued fields reject list-shaped JSON with an actionable error
    # (a raw AttributeError from .items() is useless at the CLI surface)
    with pytest.raises(ValueError, match="nan_ticks"):
        parse_fault_plan({"nan_ticks": [2]})
    with pytest.raises(ValueError, match="backend_fail"):
        parse_fault_plan('{"backend_fail": ["fused"]}')


def test_tick_fault_is_one_shot():
    """A tick-keyed fault is transient: the retry of the SAME tick succeeds."""
    inj = FaultInjector(FaultPlan(decode_fail_ticks=(3,)))
    for t in range(3):
        inj.before_decode(t)
    with pytest.raises(InjectedFault):
        inj.before_decode(3)
    inj.before_decode(3)  # retry: clean
    assert inj.injected == 1


def test_attempt_faults_model_persistent_failure():
    """Attempt-keyed faults count retries too — a run of ordinals keeps a
    tick failing through every retry (persistent failure)."""
    inj = FaultInjector(FaultPlan(decode_fail_attempts=(0, 1, 2)))
    for _ in range(3):
        with pytest.raises(InjectedFault):
            inj.before_decode(0)
    inj.before_decode(0)  # attempt 3: budget exhausted
    assert inj.injected == 3


def test_backend_fault_counts_down_and_respects_demotion():
    inj = FaultInjector(FaultPlan(backend_fail={"fused": 2}))
    with pytest.raises(BackendFault) as ei:
        inj.before_decode(0)
    assert ei.value.backend == "fused"
    # once the engine demotes the backend, its faults stop firing
    inj.before_decode(0, demoted={"fused": "mxu"})
    with pytest.raises(BackendFault):
        inj.before_decode(1)
    inj.before_decode(2)  # count exhausted
    assert inj.injected == 2


def test_corrupt_logits_nans_one_row_once():
    import numpy as np

    inj = FaultInjector(FaultPlan(nan_ticks={1: 0}))
    logits = np.zeros((2, 4), np.float32)
    clean = inj.corrupt_logits(0, logits)
    assert np.isfinite(clean).all()
    hit = inj.corrupt_logits(1, logits)
    assert np.isnan(hit[0]).all() and np.isfinite(hit[1]).all()
    assert np.isfinite(logits).all()  # never in place
    again = inj.corrupt_logits(1, logits)  # one-shot: retry decodes clean
    assert np.isfinite(again).all()


def test_prefill_and_snapshot_hooks():
    inj = FaultInjector(FaultPlan(prefill_fail_rids={4: 1}, snapshot_fail_at=(1,)))
    inj.before_prefill(3)
    with pytest.raises(InjectedFault):
        inj.before_prefill(4)
    inj.before_prefill(4)  # count exhausted -> re-admission succeeds
    inj.on_snapshot(0)
    with pytest.raises(InjectedFault):
        inj.on_snapshot(1)
    inj.on_snapshot(1)  # one-shot


def test_delay_hook_sleeps_via_injected_clock():
    slept = []
    inj = FaultInjector(
        FaultPlan(delay_ticks={2: 0.5}, every_tick_delay_s=0.1),
        sleep=slept.append,
    )
    inj.before_decode(0)
    inj.before_decode(1)
    inj.before_decode(2)
    assert slept == [pytest.approx(0.1), pytest.approx(0.1), pytest.approx(0.6)]


def test_sample_is_deterministic_in_seed():
    a = FaultPlan.sample(7, horizon=100, p_decode_fail=0.2, p_nan=0.1, max_delay_s=0.5)
    b = FaultPlan.sample(7, horizon=100, p_decode_fail=0.2, p_nan=0.1, max_delay_s=0.5)
    c = FaultPlan.sample(8, horizon=100, p_decode_fail=0.2, p_nan=0.1, max_delay_s=0.5)
    assert a == b
    assert a != c
    assert not a.is_noop()
    assert all(0 <= t < 100 for t in a.decode_fail_ticks)
