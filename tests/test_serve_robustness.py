"""Fault-tolerant serving: differential tests against the sequential oracle.

The contract under test (docs/serving-robustness.md): failures are inputs,
not outages.  A request that hits an injected fault is retried/re-admitted
under the same ``(seed, rid)`` RNG key, so its final token sequence is
bit-identical to a run with no fault at all — which is what lets every test
here diff the fault-tolerant engine against the fault-free
``serve_sequential`` oracle, token for token.
"""

import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.smoke import smoke_variant
from repro.core import dispatch
from repro.models import model_zoo as Z
from repro.runtime.faults import FaultPlan
from repro.runtime.serve_loop import (
    STATE_DEADLINE,
    STATE_FAILED,
    STATE_OK,
    Request,
    ServeEngine,
    serve_sequential,
)

MAX_LEN = 48


@pytest.fixture(autouse=True)
def _clean_demotions():
    dispatch.clear_demotions()
    yield
    dispatch.clear_demotions()


@pytest.fixture(scope="module")
def model():
    cfg = smoke_variant(get_config("granite-8b"))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, Z.prepare_serving_params(params, cfg)


def _requests(cfg, n=4, temperature=0.8, max_new=6, deadline=None):
    """Deterministic mixed-length request set (fresh objects per call, so
    engine and oracle never share mutable state)."""
    rng = np.random.default_rng(1234)
    return [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(3 + 2 * i,)).astype(np.int32),
            max_new_tokens=max_new,
            temperature=temperature,
            deadline_s=deadline,
        )
        for i in range(n)
    ]


def _oracle(model, **kw):
    cfg, params = model
    return serve_sequential(cfg, params, _requests(cfg, **kw), max_len=MAX_LEN, seed=0)


def _engine(model, **kw):
    cfg, params = model
    return ServeEngine(cfg, params, batch_slots=2, max_len=MAX_LEN, seed=0, **kw)


def _assert_token_identical(got, want):
    for g, w in zip(got, want):
        assert g.output == w.output, (
            f"rid={g.rid} diverged after faults: {g.output} != {w.output}"
        )


# ---------------------------------------------------------------------------
# (a) mid-decode failure -> retry/re-admission is bit-identical
# ---------------------------------------------------------------------------


def test_transient_tick_fault_retries_in_place(model):
    """A one-shot decode-tick fault is absorbed by the in-place retry: no
    request loses progress, outputs match the unfailed oracle exactly."""
    want = _oracle(model)
    eng = _engine(model, fault_plan=FaultPlan(decode_fail_ticks=(1, 4)))
    got = eng.run(_requests(model[0]))
    kinds = [e["kind"] for e in eng.last_events]
    assert kinds.count("step_fault") == 2
    assert "retry_tick" in kinds
    assert all(r.state == STATE_OK and r.retries == 0 for r in got)
    _assert_token_identical(got, want)


def test_nan_logits_fail_one_request_and_replay_bit_identical(model):
    """THE re-admission guarantee: NaN logits mid-generation kill ONE
    request's progress; its replay from the prompt — same (seed, rid) RNG,
    temperature > 0 — emits the exact token sequence of an unfailed run,
    and co-batched requests never notice."""
    want = _oracle(model)
    eng = _engine(model, fault_plan=FaultPlan(nan_ticks={1: 0}))
    got = eng.run(_requests(model[0]))
    kinds = [e["kind"] for e in eng.last_events]
    assert "nan_logits" in kinds and "requeue" in kinds
    assert sum(r.retries for r in got) == 1  # exactly one victim
    assert all(r.state == STATE_OK for r in got)
    _assert_token_identical(got, want)


def test_prefill_fault_readmits_bit_identical(model):
    want = _oracle(model)
    eng = _engine(model, fault_plan=FaultPlan(prefill_fail_rids={0: 1}))
    got = eng.run(_requests(model[0]))
    assert any(e["kind"] == "prefill_fault" for e in eng.last_events)
    assert got[0].retries == 1 and got[0].state == STATE_OK
    _assert_token_identical(got, want)


def test_retry_exhaustion_is_terminal_but_engine_survives(model):
    """A persistent decode failure burns the whole retry budget: requests
    end "failed" (never silently lost), and the SAME engine then serves a
    clean queue — the failure was contained to the run, not the process."""
    eng = _engine(
        model,
        fault_plan=FaultPlan(decode_fail_attempts=tuple(range(500))),
        max_retries=1,
        retry_backoff_s=0.0,
    )
    got = eng.run(_requests(model[0], n=3))
    assert all(r.state == STATE_FAILED for r in got)
    assert all(r.retries == eng.max_retries + 1 for r in got)
    # engine object still healthy: a fresh fault-free engine semantics check
    clean = _engine(model)
    again = clean.run(_requests(model[0], n=3))
    assert all(r.state == STATE_OK for r in again)
    _assert_token_identical(again, _oracle(model, n=3))


# ---------------------------------------------------------------------------
# (c) backend demotion: repeated fused failures -> pinned mxu fallback
# ---------------------------------------------------------------------------


def test_repeated_backend_failures_demote_with_zero_lost_requests(model):
    want = _oracle(model)
    eng = _engine(
        model, fault_plan=FaultPlan(backend_fail={"fused": 2}), demote_after=2
    )
    got = eng.run(_requests(model[0]))
    demotes = [e for e in eng.last_events if e["kind"] == "demote"]
    assert demotes and demotes[0]["from"] == "fused" and demotes[0]["to"] == "mxu"
    assert dispatch.demotions() == {"fused": "mxu"}
    assert dispatch.resolve_backend("fused") == "mxu"
    # zero lost: every request terminal-ok with full, oracle-exact output
    assert all(r.state == STATE_OK for r in got)
    _assert_token_identical(got, want)


def test_demotion_pins_dispatch_for_explicit_backends():
    dispatch.pin_demotion("fused", "mxu")
    assert dispatch.resolve_backend("fused") == "mxu"
    assert dispatch.resolve_backend("mxu") == "mxu"
    with pytest.raises(ValueError):
        dispatch.pin_demotion("mxu", "fused")  # would cycle
    dispatch.clear_demotions()
    assert dispatch.resolve_backend("fused") == "fused"


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_queued_request_past_deadline_is_expired_not_served(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=MAX_LEN, seed=0)
    head = Request(
        prompt=np.arange(4, dtype=np.int32) % cfg.vocab_size, max_new_tokens=4
    )
    # one slot: the second request waits behind head's (compiling) prefill
    # far longer than its deadline allows
    starved = Request(
        prompt=np.arange(5, dtype=np.int32) % cfg.vocab_size,
        max_new_tokens=4,
        deadline_s=0.01,
    )
    done = eng.run([head, starved])
    assert done[0].state == STATE_OK
    assert done[1].state == STATE_DEADLINE
    assert not done[1].output
    misses = [e for e in eng.last_events if e["kind"] == "deadline_miss"]
    assert [e["rid"] for e in misses] == [done[1].rid]


def test_running_request_past_deadline_frees_its_slot(model):
    cfg, params = model
    # 0.2 s injected latency per tick against a 0.5 s deadline: whatever the
    # compile overhead, no request can reach its 30-token budget in time
    eng = _engine(model, fault_plan=FaultPlan(every_tick_delay_s=0.2))
    reqs = _requests(cfg, n=2, temperature=0.0, max_new=30, deadline=0.5)
    done = eng.run(reqs)
    assert all(r.state == STATE_DEADLINE for r in done)
    assert all(len(r.output) < r.max_new_tokens for r in done)
    # the availability block surfaces the misses
    from repro.runtime.traffic import summarize_availability

    avail = summarize_availability(done, eng.last_events)
    assert avail["n_deadline_missed"] == 2
    assert avail["deadline_miss_rate"] == 1.0


def test_validation_rejects_bad_deadlines_and_shapes(model):
    cfg, params = model
    eng = _engine(model)
    with pytest.raises(ValueError, match="rank-1"):
        eng.run([Request(prompt=np.zeros((2, 3), np.int32), max_new_tokens=2)])
    with pytest.raises(ValueError, match="deadline_s"):
        eng.run(
            [
                Request(
                    prompt=np.zeros((4,), np.int32),
                    max_new_tokens=2,
                    deadline_s=0.0,
                )
            ]
        )
    with pytest.raises(ValueError, match="non-empty"):
        eng.run([Request(prompt=np.zeros((4,), np.int32), max_new_tokens=0)])


def test_oracle_parity_under_temperature_without_faults(model):
    """Baseline for every differential above: at T>0 the engine and oracle
    share sampling exactly (same _sample, same per-rid RNG)."""
    want = _oracle(model, temperature=1.1)
    eng = _engine(model)
    got = eng.run(_requests(model[0], temperature=1.1))
    _assert_token_identical(got, want)


# ---------------------------------------------------------------------------
# (b) crash-recoverable engine state
# ---------------------------------------------------------------------------


def test_snapshot_resume_in_process(model, tmp_path):
    """An engine built from only (config, params, snapshot_dir) finishes a
    snapshotted run token-for-token identically — nothing about the live
    process was load-bearing."""
    want = _oracle(model)
    snap = str(tmp_path / "snap")
    eng = _engine(model, snapshot_every=2, snapshot_dir=snap)
    eng.run(_requests(model[0]))
    assert any(e["kind"] == "snapshot" for e in eng.last_events)

    fresh = _engine(model, snapshot_every=2, snapshot_dir=snap)
    res = fresh.resume()
    assert [e["kind"] for e in fresh.last_events][0] == "resume"
    _assert_token_identical(sorted(res, key=lambda r: r.rid), want)


def test_resume_rejects_geometry_mismatch(model, tmp_path):
    cfg, params = model
    snap = str(tmp_path / "snap")
    eng = _engine(model, snapshot_every=1, snapshot_dir=snap)
    eng.run(_requests(cfg, n=2))
    other = ServeEngine(
        cfg, params, batch_slots=3, max_len=MAX_LEN, seed=0, snapshot_dir=snap
    )
    with pytest.raises(ValueError, match="geometry mismatch"):
        other.resume()
    empty = ServeEngine(
        cfg, params, batch_slots=2, max_len=MAX_LEN, seed=0,
        snapshot_dir=str(tmp_path / "nothing-here"),
    )
    with pytest.raises(FileNotFoundError):
        empty.resume()


def test_snapshot_write_crash_is_an_event_not_an_outage(model, tmp_path):
    want = _oracle(model)
    eng = _engine(
        model,
        fault_plan=FaultPlan(snapshot_fail_at=(0,)),
        snapshot_every=2,
        snapshot_dir=str(tmp_path / "snap"),
    )
    got = eng.run(_requests(model[0]))
    kinds = [e["kind"] for e in eng.last_events]
    assert "snapshot_failed" in kinds
    assert "snapshot" in kinds  # the next boundary succeeded
    assert all(r.state == STATE_OK for r in got)
    _assert_token_identical(got, want)


_CHILD = textwrap.dedent(
    """
    import jax, numpy as np
    from repro.configs import get_config
    from repro.configs.smoke import smoke_variant
    from repro.models import model_zoo as Z
    from repro.runtime.faults import FaultPlan
    from repro.runtime.serve_loop import Request, ServeEngine

    cfg = smoke_variant(get_config("granite-8b"))
    params = Z.prepare_serving_params(Z.init_params(jax.random.PRNGKey(0), cfg), cfg)
    rng = np.random.default_rng(1234)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=(3 + 2 * i,)).astype(np.int32),
                max_new_tokens=12, temperature=0.8)
        for i in range(4)
    ]
    eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, seed=0,
        fault_plan=FaultPlan(every_tick_delay_s=0.5),
        snapshot_every=1, snapshot_dir={snap!r},
    )
    eng.run(reqs)
    print("CHILD_FINISHED", flush=True)
    """
)


@pytest.mark.slow
def test_sigkill_mid_batch_then_resume_matches_oracle(model, tmp_path):
    """The crash-recovery acceptance test: a serving process is SIGKILLed
    mid-batch (a real subprocess, no cooperative shutdown); a fresh engine
    resumes from the last committed snapshot and completes every in-flight
    request token-for-token identical to the sequential oracle."""
    cfg, params = model
    snap = str(tmp_path / "snap")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.Popen(
        [sys.executable, "-c", _CHILD.format(snap=snap)],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
    )
    try:
        # wait for the first COMMITTED snapshot, then kill hard mid-batch
        deadline = time.time() + 240
        committed = None
        while time.time() < deadline and proc.poll() is None:
            mgr_dirs = [
                d for d in (os.listdir(snap) if os.path.isdir(snap) else [])
                if d.startswith("step_")
                and os.path.exists(os.path.join(snap, d, "_COMMITTED"))
            ]
            if mgr_dirs:
                committed = mgr_dirs
                break
            time.sleep(0.05)
        assert committed, "child never committed a snapshot"
        assert proc.poll() is None, (
            "child finished before SIGKILL: "
            + proc.stdout.read().decode(errors="replace")
        )
        time.sleep(0.6)  # land the kill strictly inside the decode loop
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=30)
        assert proc.returncode == -signal.SIGKILL
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)

    # the oracle for the child's workload (identical generator seed)
    rng = np.random.default_rng(1234)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(3 + 2 * i,)).astype(np.int32),
            max_new_tokens=12,
            temperature=0.8,
        )
        for i in range(4)
    ]
    want = serve_sequential(cfg, params, reqs, max_len=48, seed=0)

    eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, seed=0,
        snapshot_every=0, snapshot_dir=snap,
    )
    res = sorted(eng.resume(), key=lambda r: r.rid)
    assert all(r.state == STATE_OK for r in res)
    for got, exp in zip(res, want):
        assert got.output == exp.output, (
            f"rid={got.rid}: resumed run diverged from oracle after SIGKILL: "
            f"{got.output} != {exp.output}"
        )
