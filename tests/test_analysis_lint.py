"""Pass 2 (AST lint) unit tests: per-rule fixtures, symbol computation,
allowlist load/match/staleness, and the production-tree gate."""

import os
import textwrap

import pytest

from repro.analysis import findings as F
from repro.analysis import lint

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(REPO, "analysis", "fixtures")


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# fixtures: every rule fires on the bad file, none on the good file
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "rule", ["RNG001", "RNG002", "TIME001", "TRACE001", "DTYPE001", "MUT001"]
)
def test_rule_fires_on_bad_fixture(rule):
    found = lint.lint_file(os.path.join(FIXTURES, "lint_bad.py"), root=REPO)
    assert rule in _rules(found), f"{rule} missed its seeded fixture"


def test_good_fixture_is_clean():
    found = lint.lint_file(os.path.join(FIXTURES, "lint_good.py"), root=REPO)
    assert found == [], [f"{f.rule}:{f.line}" for f in found]


def test_bad_fixture_paths_are_repo_relative():
    found = lint.lint_file(os.path.join(FIXTURES, "lint_bad.py"), root=REPO)
    assert all(f.path == "analysis/fixtures/lint_bad.py" for f in found)


# ---------------------------------------------------------------------------
# targeted rule behavior
# ---------------------------------------------------------------------------


def _lint(src, rules=None):
    return lint.lint_source(textwrap.dedent(src), "t.py", rules)


def test_rng002_eval_shape_exempt():
    found = _lint(
        """
        import jax
        def shapes(fn):
            return jax.eval_shape(fn, jax.random.PRNGKey(0))
        def values():
            return jax.random.PRNGKey(0)
        """,
        ["RNG002"],
    )
    assert len(found) == 1
    assert found[0].symbol == "values"


def test_rng002_threaded_seed_ok():
    found = _lint(
        """
        import jax
        def make(seed):
            return jax.random.PRNGKey(seed)
        """,
        ["RNG002"],
    )
    assert found == []


def test_time001_only_inside_jit():
    found = _lint(
        """
        import time, jax
        def wall():
            return time.time()
        @jax.jit
        def traced(x):
            return x + time.perf_counter()
        """,
        ["TIME001"],
    )
    assert [f.symbol for f in found] == ["traced"]


def test_trace001_one_finding_per_branch():
    found = _lint(
        """
        import jax.numpy as jnp
        def f(x):
            if jnp.any(x) and jnp.all(x):
                return x
        """,
        ["TRACE001"],
    )
    assert len(found) == 1


def test_trace001_ignores_dtype_introspection():
    # jnp.issubdtype operates on dtypes, not traced values — must not fire
    found = _lint(
        """
        import jax.numpy as jnp
        def f(x):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x
        """,
        ["TRACE001"],
    )
    assert found == []


def test_symbol_is_nested_dotted_chain():
    found = _lint(
        """
        import numpy as np
        def outer():
            def inner():
                np.random.seed(0)
            return inner
        """,
        ["RNG001"],
    )
    assert found[0].symbol == "outer.inner"


def test_mut001_kwonly_defaults():
    found = _lint("def f(x, *, t={}):\n    return t\n", ["MUT001"])
    assert _rules(found) == {"MUT001"}


# ---------------------------------------------------------------------------
# allowlist
# ---------------------------------------------------------------------------


def _entry(**kw):
    base = dict(rule="DTYPE001", file="src/*.py", symbol="*", reason="r")
    base.update(kw)
    return F.AllowEntry(**base)


def _finding(**kw):
    base = dict(
        rule="DTYPE001", path="src/a.py", line=3, symbol="f", message="m"
    )
    base.update(kw)
    return F.Finding(**base)


def test_allowlist_filter_and_stale():
    allow = F.Allowlist([_entry(), _entry(rule="MUT001", file="never/*")])
    kept, suppressed = allow.filter([_finding(), _finding(rule="RNG001")])
    assert [f.rule for f in kept] == ["RNG001"]
    assert [f.rule for f in suppressed] == ["DTYPE001"]
    assert [e.rule for e in allow.stale_entries()] == ["MUT001"]


def test_allowlist_symbol_pattern():
    allow = F.Allowlist([_entry(symbol="init_*")])
    kept, suppressed = allow.filter(
        [_finding(symbol="init_cache"), _finding(symbol="decode")]
    )
    assert [f.symbol for f in kept] == ["decode"]
    assert [f.symbol for f in suppressed] == ["init_cache"]


def test_allowlist_rejects_missing_reason(tmp_path):
    p = tmp_path / "allow.toml"
    p.write_text('[[allow]]\nrule = "DTYPE001"\nfile = "a"\nsymbol = "b"\n')
    with pytest.raises(ValueError, match="reason"):
        F.Allowlist.load(str(p))


def test_checked_in_allowlist_loads():
    allow = F.Allowlist.load(os.path.join(REPO, "analysis", "allowlist.toml"))
    assert allow.entries
    assert all(e.reason for e in allow.entries)


# ---------------------------------------------------------------------------
# the gate CI enforces: the production tree is clean modulo the allowlist
# ---------------------------------------------------------------------------


def test_src_tree_clean_under_allowlist():
    found = lint.lint_paths(os.path.join(REPO, "src"), root=REPO)
    allow = F.Allowlist.load(os.path.join(REPO, "analysis", "allowlist.toml"))
    kept, _ = allow.filter(found)
    assert kept == [], F.render_text(kept)
    assert allow.stale_entries() == [], "stale allowlist entries"
