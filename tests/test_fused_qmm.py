"""Fused bit-serial QMM kernel: exact parity vs the ref oracle.

The exactness contract (see ``kernels/fused_qmm.py``): the integer core is
bit-exact always; the fp32 epilogue is bit-exact whenever its arithmetic is
exact, which the *dyadic* fixtures guarantee — power-of-two scales with
offsets that are dyadic multiples of them (``offset = -scale * 2**(bits-1)``,
the symmetric-quantizer shape).  Under those coefficients every epilogue term
is exactly representable, so fma-vs-mul/add compilation differences cannot
appear and ``assert_array_equal`` is the right assertion.  Real quantizer
scales are checked separately to float tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import flow_abstraction as FA
from repro.core import packing
from repro.core import qmm as QE
from repro.core import quantization as Q
from repro.core.quantization import QuantTensor
from repro.kernels import ops, ref

RNG = np.random.default_rng(17)

# tile-aligned, ragged-everything, tiny, and mid-size K-ragged
SHAPES = [(64, 512, 128), (37, 300, 45), (5, 64, 3), (16, 96, 24)]
# W1A1, W1A8, W1A4, A8xA8, A4xA4
PRECISIONS = [(1, 1), (8, 1), (4, 1), (8, 8), (4, 4)]


def _dyadic_qt(shape, bits, scale_shape):
    """QuantTensor with dyadic coefficients: the bit-exact fixture."""
    mant = RNG.integers(0, 2**bits, size=shape).astype(
        np.uint8 if bits <= 8 else np.int32
    )
    exps = RNG.integers(-4, 3, size=scale_shape)
    scale = (2.0**exps).astype(np.float32)
    offset = (-scale * (2 ** (bits - 1))).astype(np.float32)
    return QuantTensor(
        mantissa=jnp.asarray(mant),
        scale=jnp.asarray(scale),
        offset=jnp.asarray(offset),
        bits=bits,
    )


def _oracle(x, w, m, k, n):
    """ref.fused_qmm_ref over the same planes/coefficients ops.qmm_fused uses."""
    a_planes = packing.pack_bitplanes(
        x.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), x.bits, axis=-1
    )
    b_planes = packing.pack_bitplanes(
        w.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), w.bits, axis=-2
    )
    f32 = jnp.float32
    return ref.fused_qmm_ref(
        a_planes,
        b_planes,
        jnp.broadcast_to(jnp.asarray(x.scale, f32), (m, 1)),
        jnp.broadcast_to(jnp.asarray(x.offset, f32), (m, 1)),
        jnp.broadcast_to(jnp.asarray(w.scale, f32), (1, n)),
        jnp.broadcast_to(jnp.asarray(w.offset, f32), (1, n)),
        k,
    )


# ---------------------------------------------------------------------------
# exact parity vs the oracle (dyadic coefficients -> bit-exact, all modes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("act_bits,weight_bits", PRECISIONS)
def test_fused_matches_oracle_bit_exact(m, k, n, act_bits, weight_bits):
    x = _dyadic_qt((m, k), act_bits, (m, 1))  # per-token dyadic scales
    w = _dyadic_qt((k, n), weight_bits, (1, n))  # per-channel dyadic scales
    got = ops.qmm_fused(x, w)
    want = _oracle(x, w, m, k, n)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_packed_weight_serving_path_bit_exact():
    """1-bit weights arrive pre-packed (with a precomputed colsum) from
    ``pack_linear_for_serving``; the kernel consumes the packed planes
    directly and ignores the colsum — still bit-exact vs the oracle."""
    m, k, n = 37, 300, 45
    x = _dyadic_qt((m, k), 8, (m, 1))
    w = _dyadic_qt((k, n), 1, (1, n))
    want = _oracle(x, w, m, k, n)
    colsum = FA.weight_corrections(w)
    wp = w.pack(axis=0)
    got = ops.qmm_fused(x, wp, w_colsum=colsum)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # a *wrong* colsum must not change anything: it is computed in-kernel
    got2 = ops.qmm_fused(x, wp, w_colsum=colsum + 999)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))


def test_fused_integer_core_is_exact_mantissa_matmul():
    """With scale=1, offset=0 the output *is* the integer MM — exact."""
    m, k, n, bits = 16, 200, 24, 8
    a = RNG.integers(0, 2**bits, size=(m, k)).astype(np.int64)
    b = RNG.integers(0, 2**bits, size=(k, n)).astype(np.int64)
    one = lambda s: jnp.ones(s, jnp.float32)  # noqa: E731
    x = QuantTensor(
        mantissa=jnp.asarray(a.astype(np.uint8)),
        scale=one((m, 1)),
        offset=jnp.zeros((m, 1), jnp.float32),
        bits=bits,
    )
    w = QuantTensor(
        mantissa=jnp.asarray(b.astype(np.uint8)),
        scale=one((1, n)),
        offset=jnp.zeros((1, n), jnp.float32),
        bits=bits,
    )
    got = ops.qmm_fused(x, w)
    np.testing.assert_array_equal(
        np.asarray(got), (a @ b).astype(np.float32)
    )


# ---------------------------------------------------------------------------
# registry wiring + real quantizer scales
# ---------------------------------------------------------------------------


def test_fused_dispatches_through_qmm_backend_kwarg():
    m, k, n = 16, 96, 24
    x = _dyadic_qt((m, k), 4, (m, 1))
    w = _dyadic_qt((k, n), 1, (1, n))
    np.testing.assert_array_equal(
        np.asarray(QE.qmm(x, w, backend="fused")),
        np.asarray(ops.qmm_fused(x, w)),
    )


@pytest.mark.parametrize("act_bits,weight_bits", [(1, 1), (8, 1), (8, 8)])
def test_fused_real_quantizer_scales_match_mxu(act_bits, weight_bits):
    """Arbitrary (non-dyadic) scales: agreement to fp32 tolerance — the
    integer core is still exact; only the epilogue rounding may differ."""
    m, k, n = 24, 160, 20
    xf = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    wf = jnp.asarray(RNG.standard_normal((k, n)).astype(np.float32))
    xq = Q.quantize_activation(xf, act_bits)
    wq = (
        Q.quantize_weight(wf, weight_bits)
        if weight_bits == 1
        else Q.quantize_activation(wf, weight_bits)
    )
    got = QE.qmm(xq, wq, backend="fused")
    want = QE.qmm(xq, wq, backend="mxu")
    tol = 3e-5 * max(1.0, float(jnp.max(jnp.abs(want))))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


def test_fused_out_dtype_cast():
    m, k, n = 8, 64, 16
    x = _dyadic_qt((m, k), 4, (m, 1))
    w = _dyadic_qt((k, n), 1, (1, n))
    out = ops.qmm_fused(x, w, out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16


def test_fused_rejects_non_rank2():
    x3 = QuantTensor(
        mantissa=jnp.zeros((2, 8, 64), jnp.uint8),
        scale=jnp.float32(1.0),
        offset=jnp.float32(0.0),
        bits=8,
    )
    w = _dyadic_qt((64, 16), 1, (1, 16))
    with pytest.raises(ValueError, match="rank-2"):
        ops.qmm_fused(x3, w)
