"""Traffic generator determinism + BENCH_serve.json schema contract."""

import json

import numpy as np
import pytest

from repro.runtime.serve_loop import STATE_DEADLINE, STATE_FAILED, STATE_OK, Request
from repro.runtime.traffic import (
    BENCH_REQUIRED_KEYS,
    TrafficConfig,
    generate_requests,
    load_bench,
    save_bench,
    summarize_availability,
    summarize_bench,
    validate_bench,
)

VOCAB = 256


def test_generator_is_deterministic():
    tc = TrafficConfig(n_requests=12, rate_rps=5.0, seed=123)
    a = generate_requests(tc, VOCAB)
    b = generate_requests(tc, VOCAB)
    assert len(a) == len(b) == 12
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt, rb.prompt)
        assert ra.max_new_tokens == rb.max_new_tokens
        assert ra.arrival_s == rb.arrival_s
    c = generate_requests(TrafficConfig(n_requests=12, rate_rps=5.0, seed=124), VOCAB)
    assert any(not np.array_equal(ra.prompt, rc.prompt) for ra, rc in zip(a, c))


def test_generator_respects_config():
    tc = TrafficConfig(
        n_requests=50, rate_rps=20.0, prompt_len=(3, 7), new_tokens=(2, 5), seed=0
    )
    reqs = generate_requests(tc, VOCAB)
    assert all(3 <= len(r.prompt) <= 7 for r in reqs)
    assert all(2 <= r.max_new_tokens <= 5 for r in reqs)
    assert all(0 <= t < VOCAB for r in reqs for t in r.prompt.tolist())
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr) and arr[0] > 0  # Poisson arrivals, increasing
    # rate <= 0 -> everything arrives at t=0 (closed burst)
    burst = generate_requests(TrafficConfig(n_requests=5, rate_rps=0.0), VOCAB)
    assert all(r.arrival_s == 0.0 for r in burst)


def _served_requests():
    """A hand-built served set with known timing."""
    reqs = []
    for i in range(4):
        r = Request(prompt=np.zeros((4,), np.int32), max_new_tokens=3, arrival_s=0.1 * i)
        r.output = [1, 2, 3]
        base = 0.1 * i + 0.05
        r.token_times = [base, base + 0.01, base + 0.02]
        reqs.append(r)
    return reqs


def test_bench_summary_schema_and_roundtrip(tmp_path):
    summary = summarize_bench(_served_requests(), wall_s=2.0, config={"arch": "x"})
    for k in BENCH_REQUIRED_KEYS:
        assert k in summary
    assert summary["rps"] == pytest.approx(2.0)  # 4 requests / 2 s
    assert summary["n_tokens"] == 12
    assert summary["p50_ms"] > 0 and summary["p99_ms"] >= summary["p50_ms"]
    assert summary["ttft_p50_ms"] == pytest.approx(50.0)

    path = tmp_path / "BENCH_serve.json"
    save_bench(str(path), summary)
    doc = json.loads(path.read_text())  # round-trips through plain json
    assert doc["config"] == {"arch": "x"}
    assert load_bench(str(path)) == doc


def _avail():
    return {"success_rate": 1.0, "deadline_miss_rate": 0.0, "retries": 0}


def test_bench_validation_rejects_bad_docs():
    with pytest.raises(ValueError, match="missing"):
        validate_bench({"rps": 1.0})
    with pytest.raises(ValueError, match="numeric"):
        validate_bench(
            {"rps": "fast", "p50_ms": 1, "p99_ms": 2, "config": {},
             "availability": _avail()}
        )
    with pytest.raises(ValueError, match="object"):
        validate_bench(
            {"rps": 1, "p50_ms": 1, "p99_ms": 2, "config": "x",
             "availability": _avail()}
        )
    # schema v2: the availability block is required and typed
    with pytest.raises(ValueError, match="missing"):
        validate_bench({"rps": 1, "p50_ms": 1, "p99_ms": 2, "config": {}})
    with pytest.raises(ValueError, match="availability"):
        validate_bench(
            {"rps": 1, "p50_ms": 1, "p99_ms": 2, "config": {},
             "availability": "fine"}
        )
    with pytest.raises(ValueError, match="success_rate"):
        validate_bench(
            {"rps": 1, "p50_ms": 1, "p99_ms": 2, "config": {},
             "availability": {"deadline_miss_rate": 0.0, "retries": 0}}
        )


def test_availability_summary_counts_states_and_events():
    reqs = _served_requests()
    reqs[0].state = STATE_OK
    reqs[1].state = STATE_OK
    reqs[2].state = STATE_FAILED
    reqs[2].retries = 3
    reqs[2].output = []
    reqs[2].token_times = []
    reqs[3].state = STATE_DEADLINE
    reqs[3].retries = 1
    events = [
        {"kind": "step_fault", "t": 0.1},
        {"kind": "retry_tick", "t": 0.1},
        {"kind": "nan_logits", "t": 0.2, "rid": 2},
        {"kind": "demote", "t": 0.3, "from": "fused", "to": "mxu"},
        {"kind": "snapshot", "t": 0.4, "tick": 4},
        {"kind": "decode_tick", "t": 0.5},
    ]
    avail = summarize_availability(reqs, events)
    assert avail["n_ok"] == 2
    assert avail["n_failed"] == 1
    assert avail["n_deadline_missed"] == 1
    assert avail["success_rate"] == pytest.approx(0.5)
    assert avail["deadline_miss_rate"] == pytest.approx(0.25)
    assert avail["retries"] == 4
    assert avail["faults"] == 2  # step_fault + nan_logits, not retries/ticks
    assert avail["demotions"] == 1
    assert avail["snapshots"] == 1
    assert avail["p99_under_faults_ms"] > 0


def test_availability_rides_in_bench_summary():
    summary = summarize_bench(
        _served_requests(), wall_s=2.0, config={"arch": "x"},
        events=[{"kind": "step_fault", "t": 0.1}],
    )
    validate_bench(summary)
    avail = summary["availability"]
    # hand-built requests never drove the engine state machine: the
    # output-presence fallback counts them all ok
    assert avail["success_rate"] == 1.0 and avail["n_ok"] == 4
    assert avail["faults"] == 1
    # availability block round-trips through plain JSON
    assert json.loads(json.dumps(avail)) == avail


def test_traffic_config_json_serializable():
    tc = TrafficConfig(prompt_len=(2, 9))
    d = tc.to_dict()
    assert json.loads(json.dumps(d)) == d
