"""Data pipeline invariants: determinism, sharding, checkpoint/resume."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = TokenPipeline(_cfg())
    b = TokenPipeline(_cfg())
    for _ in range(3):
        np.testing.assert_array_equal(a.next()["tokens"], b.next()["tokens"])


def test_shards_are_disjoint_slices_of_global():
    full = TokenPipeline(_cfg(), shard_index=0, num_shards=1)
    s0 = TokenPipeline(_cfg(), shard_index=0, num_shards=2)
    s1 = TokenPipeline(_cfg(), shard_index=1, num_shards=2)
    b_full = full.next()["tokens"]
    b0, b1 = s0.next()["tokens"], s1.next()["tokens"]
    assert b0.shape == (4, 32) and b1.shape == (4, 32)
    # shards must differ from each other (disjoint random streams)
    assert not np.array_equal(b0, b1)


def test_resume_from_cursor_is_bit_identical():
    a = TokenPipeline(_cfg())
    for _ in range(5):
        a.next()
    state = a.state()
    want = a.next()["tokens"]
    b = TokenPipeline(_cfg())
    b.restore(state)
    np.testing.assert_array_equal(b.next()["tokens"], want)


def test_reshard_keeps_cursor():
    a = TokenPipeline(_cfg(), shard_index=0, num_shards=2)
    a.next(), a.next()
    b = a.reshard(0, 4)
    assert b.cursor == 2
    assert b.local_batch == 2


def test_seed_mismatch_rejected():
    a = TokenPipeline(_cfg())
    with pytest.raises(ValueError):
        b = TokenPipeline(_cfg(seed=8))
        b.restore(a.state())


def test_stream_is_learnable_not_uniform():
    """The n-gram echo must create predictable structure (loss can drop)."""
    p = TokenPipeline(_cfg(seq_len=256, global_batch=4))
    toks = p.next()["tokens"]
    # echo property: token[t] == (token[t-3] + shift) % V with prob ~0.5,
    # measured against the FINAL stream (echo chains compound, so the
    # observable rate is ~p*(p + (1-p)/1) ~ 0.25-0.5); uniform would be ~1/V.
    echo = (np.roll(toks, 3, axis=1) + p._shift) % 1000
    match = (toks[:, 3:] == echo[:, 3:]).mean()
    assert 0.15 < match < 0.7, f"echo rate {match}"


def test_frontend_embeddings_emitted():
    p = TokenPipeline(_cfg(frontend_positions=12, frontend_dim=24))
    b = p.next()
    assert b["frontend"].shape == (8, 12, 24)
    assert b["frontend"].dtype == np.float32
