"""Packing/unpacking invariants (property-based)."""

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional test dep; gate, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core import packing as P


@st.composite
def _arrays(draw, bits):
    rows = draw(st.integers(1, 7))
    length = draw(st.integers(1, 200))
    seed = draw(st.integers(0, 2**32 - 1))
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**bits, size=(rows, length)).astype(np.int32)


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_pack_roundtrip(bits, data):
    x = data.draw(_arrays(bits))
    for axis in (0, 1, -1):
        packed = P.pack_bits(jnp.asarray(x), bits, axis=axis)
        assert packed.dtype == jnp.uint32
        assert packed.shape[axis] == P.packed_len(x.shape[axis], bits)
        out = P.unpack_bits(packed, bits, x.shape[axis], axis=axis)
        np.testing.assert_array_equal(np.asarray(out), x)


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_bitplane_roundtrip(bits, data):
    x = data.draw(_arrays(bits))
    planes = P.to_bitplanes(jnp.asarray(x), bits)
    assert planes.shape == (bits,) + x.shape
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    back = P.from_bitplanes(planes, bits)
    np.testing.assert_array_equal(np.asarray(back), x.astype(np.uint32))


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_bitplane_weighted_sum_identity(data):
    """x == sum_i 2^i plane_i — the bit-serial schedule's correctness basis."""
    bits = data.draw(st.sampled_from([2, 4, 8]))
    x = data.draw(_arrays(bits))
    planes = np.asarray(P.to_bitplanes(jnp.asarray(x), bits))
    recon = sum((planes[i].astype(np.int64) << i) for i in range(bits))
    np.testing.assert_array_equal(recon, x)


def test_np_twin_matches_jax():
    rng = np.random.default_rng(0)
    for bits in (1, 2, 4, 8):
        x = rng.integers(0, 2**bits, size=(9, 100)).astype(np.int32)
        a = np.asarray(P.pack_bits(jnp.asarray(x), bits, axis=-1))
        b = P.pack_bits_np(x, bits, axis=-1)
        np.testing.assert_array_equal(a, b)


def test_packed_len_tail_padding_is_zero():
    x = jnp.ones((1, 33), jnp.int32)
    packed = P.pack_bits(x, 1, axis=-1)
    assert packed.shape == (1, 2)
    # 33rd bit set, rest of word 2 must be zero-padded
    assert int(packed[0, 1]) == 1


def test_values_per_word_rejects_bad_bits():
    with pytest.raises(ValueError):
        P.values_per_word(3)
