"""The frozen analytical model must reproduce the paper's Table II."""

import pytest

from repro.core import energy_model as em
from repro.core.precision import MODES


@pytest.fixture(scope="module")
def workload():
    return em.bert_base_qmm_workload()


@pytest.mark.parametrize("name", ["BiT", "BinaryBERT", "BiBERT"])
def test_table2_throughput_within_1pct(workload, name):
    mode = MODES["W1A1"]
    overhead = em.BENCHMARK_OVERHEADS[name]
    gops, _ = em.throughput_gops(workload, mode, em.ZCU102_BETA, overhead)
    assert abs(gops - em.PAPER_TABLE2[name]["gops"]) / em.PAPER_TABLE2[name]["gops"] < 0.01


@pytest.mark.parametrize("name", ["BiT", "BinaryBERT", "BiBERT"])
def test_table2_power_within_1pct(workload, name):
    mode = MODES["W1A1"]
    overhead = em.BENCHMARK_OVERHEADS[name]
    p = em.power_w(workload, mode, em.ZCU102_BETA, overhead)
    assert abs(p - em.PAPER_TABLE2[name]["power_w"]) / em.PAPER_TABLE2[name]["power_w"] < 0.01


@pytest.mark.parametrize("name", ["BiT", "BinaryBERT", "BiBERT"])
def test_table2_efficiency_within_1pct(workload, name):
    mode = MODES["W1A1"]
    overhead = em.BENCHMARK_OVERHEADS[name]
    eff = em.energy_efficiency(workload, mode, em.ZCU102_BETA, overhead)
    ref = em.PAPER_TABLE2[name]["gops_per_w"]
    assert abs(eff - ref) / ref < 0.01


def test_fig5_trend_monotone(workload):
    """Fig. 5: lower activation precision -> higher throughput AND higher
    energy efficiency (while accuracy drops — accuracy is a model property,
    exercised in the QAT example)."""
    oh = em.BENCHMARK_OVERHEADS["BiT"]
    gops = []
    eff = []
    for m in ("W1A8", "W1A4", "W1A2", "W1A1"):
        g, _ = em.throughput_gops(workload, MODES[m], em.ZCU102_BETA, oh)
        gops.append(g)
        eff.append(em.energy_efficiency(workload, MODES[m], em.ZCU102_BETA, oh))
    assert gops == sorted(gops), "throughput must rise as precision drops"
    assert eff == sorted(eff), "efficiency must rise as precision drops"


def test_average_efficiency_matches_headline(workload):
    """Paper abstract: 'average energy efficiency of 174 GOPS/W'."""
    mode = MODES["W1A1"]
    effs = [
        em.energy_efficiency(workload, mode, em.ZCU102_BETA, oh)
        for oh in em.BENCHMARK_OVERHEADS.values()
    ]
    avg = sum(effs) / len(effs)
    assert abs(avg - 174.0) < 2.0


def test_peak_rate_matches_datapath():
    """Peak W1A1 rate = 2 ops * N * J * pack(8) * f = 1556.5 GOPS."""
    hw = em.ZCU102_BETA
    assert abs(hw.peak_gops(MODES["W1A1"]) - 2 * 2 * 256 * 8 * 190e6 / 1e9) < 1e-6


def test_bitserial_slows_act_act():
    hw = em.ZCU102_BETA
    s = em.QMMShape(64, 64, 64, "act_act")
    c4 = em.qmm_cycles(s, MODES["W1A4"], hw)
    c1 = em.qmm_cycles(s, MODES["W1A1"], hw)
    assert c4 > c1 * 4  # 4 bit-planes serially, plus lower packing

def test_power_calibration_recovers_constants():
    pts = []
    wl = em.bert_base_qmm_workload()
    for name, oh in em.BENCHMARK_OVERHEADS.items():
        gops, _ = em.throughput_gops(wl, MODES["W1A1"], em.ZCU102_BETA, oh)
        pts.append((gops / 2e3, em.PAPER_TABLE2[name]["power_w"]))
    p_static, p_dyn = em.calibrate_power(pts)
    assert abs(p_static - em.ZCU102_BETA.p_static_w) < 0.05
    assert abs(p_dyn - em.ZCU102_BETA.p_dyn_w_per_tmacs) < 0.2
