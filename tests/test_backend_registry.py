"""Backend-registry contract: registration, capabilities, enumeration.

The registry is the QMM engine's extension point — these tests pin the
contract a third-party backend relies on: register by name, show up in
every consumer (``qmm`` validation, ``QuantConfig``, autotune candidates,
``dispatch.BACKENDS``), and disappear cleanly on unregister.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.base import QuantConfig
from repro.core import backend_registry as BR
from repro.core import dispatch
from repro.core import qmm as QE
from repro.core import quantization as Q

RNG = np.random.default_rng(11)

BUILTINS = ("mxu", "popcount", "pallas", "fused")


def _quant_pair(m=8, k=64, n=16, act_bits=4):
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((k, n)).astype(np.float32))
    return Q.quantize_activation(x, act_bits), Q.quantize_weight(w, 1)


def _toy_run(x, w, *, w_colsum=None, out_dtype=jnp.float32):
    # a "new" backend is allowed to delegate; identity is the name
    return QE.qmm(x, w, backend="mxu", w_colsum=w_colsum, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# enumeration of the built-ins
# ---------------------------------------------------------------------------


def test_builtins_register_in_candidate_order():
    names = BR.backend_names()
    assert tuple(names[:4]) == BUILTINS
    specs = {s.name: s for s in BR.backend_specs()}
    assert specs["fused"].rank2_only and specs["fused"].needs_unsigned_mantissas
    assert specs["popcount"].needs_unsigned_mantissas
    assert not specs["mxu"].rank2_only
    # every built-in declares a traffic model for the roofline bench
    for b in BUILTINS:
        assert specs[b].traffic_model is not None


def test_get_backend_unknown_name_lists_registered():
    with pytest.raises(ValueError, match="registered backends: mxu"):
        BR.get_backend("fpga")


def test_qmm_rejects_unknown_backend_with_registry_names():
    xq, wq = _quant_pair()
    with pytest.raises(ValueError, match="unknown backend 'fpga'"):
        QE.qmm(xq, wq, backend="fpga")


# ---------------------------------------------------------------------------
# registration round trip: a new backend reaches every consumer
# ---------------------------------------------------------------------------


def test_register_unregister_round_trip_reaches_all_consumers():
    try:
        BR.register_backend("toy", description="delegates to mxu")(_toy_run)
        assert "toy" in BR.backend_names()
        assert BR.get_backend("toy").run is _toy_run
        # deprecated dynamic view follows the registry
        assert "toy" in dispatch.BACKENDS
        # config validation accepts it (and its error message would list it)
        q = QuantConfig(backend="toy")
        assert q.backend == "toy"
        # autotune candidate with zero dispatcher edits
        assert "toy" in dispatch.candidate_backends(8, 64, 16, 4, 1)
        # and it actually runs through qmm()
        xq, wq = _quant_pair()
        np.testing.assert_array_equal(
            np.asarray(QE.qmm(xq, wq, backend="toy")),
            np.asarray(QE.qmm(xq, wq, backend="mxu")),
        )
    finally:
        BR.unregister("toy")
    assert "toy" not in BR.backend_names()
    with pytest.raises(ValueError, match="unknown backend 'toy'"):
        BR.get_backend("toy")
    with pytest.raises(ValueError, match="unknown backend 'toy'"):
        QuantConfig(backend="toy")


def test_duplicate_and_reserved_names_rejected():
    try:
        BR.register_backend("toy")(_toy_run)
        with pytest.raises(ValueError, match="already registered"):
            BR.register_backend("toy")(_toy_run)
    finally:
        BR.unregister("toy")
    for bad in ("", "auto"):
        with pytest.raises(ValueError, match="invalid backend name"):
            BR.register_backend(bad)(_toy_run)
    BR.unregister("never-registered")  # no-op, not an error


# ---------------------------------------------------------------------------
# capability flags drive candidate filtering
# ---------------------------------------------------------------------------


def test_precision_capability_filters_candidates():
    try:
        BR.register_backend("w1a1only", precisions=frozenset({(1, 1)}))(_toy_run)
        assert "w1a1only" in BR.candidate_names(8, 64, 16, 1, 1)
        assert "w1a1only" not in BR.candidate_names(8, 64, 16, 8, 1)
    finally:
        BR.unregister("w1a1only")


def test_rank2_and_probe_capabilities_filter_candidates():
    try:
        BR.register_backend(
            "small2d", rank2_only=True, probe=lambda m, k, n: m * k * n <= 1024
        )(_toy_run)
        assert "small2d" in BR.candidate_names(4, 8, 8, 1, 1)
        assert "small2d" not in BR.candidate_names(4, 8, 8, 1, 1, rank2=False)
        assert "small2d" not in BR.candidate_names(64, 64, 64, 1, 1)
    finally:
        BR.unregister("small2d")


def test_fused_is_an_autotune_candidate_and_keys_the_cache():
    """The acceptance criterion: "fused" appears as a dispatchable autotune
    candidate — in the eligible set, timed, and recorded in the cache key."""
    from repro.kernels import ops

    if not ops.on_tpu():
        # off-TPU the interpret probe gates on problem size; use a small one
        assert "fused" in dispatch.candidate_backends(8, 64, 16, 1, 1)
    it = iter([4.0, 3.0, 2.0, 1.0])  # registry order: mxu, popcount, pallas, fused
    cache = dispatch.AutotuneCache(timer=lambda fn: next(it))
    assert cache.choose(8, 64, 16, 1, 1) == "fused"  # fake-timed winner
    (key,) = cache.entries.keys()
    assert "fused" in key.candidates
    (rec,) = cache.entries.values()
    assert "fused" in rec.timings_us
