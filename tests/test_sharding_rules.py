"""Sharding-rule unit tests: every param/cache leaf gets a legal spec.

Legality = each sharded dim divisible by its axis size, packing never split
(packed K-words stay whole), and the rules cover all 10 archs' pytrees
without falling through to errors.  Uses abstract (eval_shape) pytrees, so
the FULL configs are checked — this is the same machinery the 512-device
dry-run uses, minus XLA.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.launch.mesh import abstract_mesh

from repro.configs import ASSIGNED, get_config
from repro.models import model_zoo as Z
from repro.runtime import sharding as SH


@pytest.fixture(scope="module")
def mesh():
    dev = np.array(jax.devices()[:1] * 1)
    # spec-level tests only need axis names/sizes; build an abstract mesh
    return abstract_mesh((16, 16), ("data", "model"))


def _check_tree(tree, shardings, mesh):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    flat_sh = jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat) == len(flat_sh)
    for (path, leaf), sh in zip(flat, flat_sh):
        spec = sh.spec
        shape = leaf.shape
        assert len(spec) <= len(shape), f"{path}: spec {spec} rank > {shape}"
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
            assert shape[i] % size == 0, (
                f"{jax.tree_util.keystr(path)}: dim {i} ({shape[i]}) "
                f"not divisible by {axes} ({size})"
            )


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_param_specs_legal(arch, mesh):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: Z.init_params(k, cfg), jax.random.PRNGKey(0))
    sh = SH.params_shardings(params, mesh, fsdp=True)
    _check_tree(params, sh, mesh)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_serving_param_specs_legal(arch, mesh):
    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        jax.random.PRNGKey(0),
    )
    sh = SH.params_shardings(params, mesh)
    _check_tree(params, sh, mesh)


@pytest.mark.parametrize("arch", ["granite-8b", "deepseek-v3-671b", "mamba2-130m", "gemma3-27b"])
def test_cache_specs_legal(arch, mesh):
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: Z.init_cache(128, 32768, cfg))
    sh = SH.cache_shardings(cache, mesh, 128)
    _check_tree(cache, sh, mesh)


def test_row_parallel_never_splits_packed_words():
    """Row-parallel packed weights shard the WORD axis; 16-way sharding of
    K/32 words requires K % (32*16) == 0 — check the real archs satisfy it
    or the rule falls back to replication."""
    mesh = abstract_mesh((16, 16), ("data", "model"))
    for arch in ASSIGNED:
        cfg = get_config(arch)
        params = jax.eval_shape(
            lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
            jax.random.PRNGKey(0),
        )
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        for path, leaf in flat:
            names = [getattr(k, "key", str(k)) for k in path]
            if names[-1] == "w_packed":
                spec = SH.param_pspec(tuple(names), leaf.shape, mesh)
                for i, entry in enumerate(spec):
                    if entry is not None:
                        assert leaf.shape[i] % 16 == 0


def test_long500k_batch1_uses_sequence_sharding():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    spec = SH.logical_batch_spec(1, 524288, mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data")


def test_train4k_batch_sharded_over_pods_and_data():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = SH.logical_batch_spec(256, 4096, mesh)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), None)
