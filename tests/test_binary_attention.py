"""Bitwise XNOR-popcount attention: differential oracle + properties.

Three layers of proof for the scores backend family (PR 10):

1. kernel parity — every registered scores core (`binary`, `mxu`, `float`)
   is BIT-EXACT against the pure-NumPy oracle ``ref.binary_attn_scores_ref``
   over a shape grid including ragged sequence lengths, head dims that are
   not multiples of 32, GQA head expansion, and T beyond the popcount
   chunk size;
2. engine differential — serving bit-bert-base with ``attn.qk -> "binary"``
   (autotuned core) produces token-for-token the greedy outputs of the
   pinned ``"float"`` score core (the deterministic oracle path), through
   both ``serve_sequential`` and the slot-managed ``ServeEngine``;
3. site semantics — overriding ``attn.qk`` must NOT leak into
   ``attn.qk_latent`` (the MLA latent site is addressed separately), and
   rows of the packed K cache beyond the cursor must be invisible to decode.

Property tests (hypothesis, optional dep) cover the binarizer's monotonicity
and scale-equivariance and the popcount self-similarity identity.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.smoke import smoke_variant
from repro.core import backend_registry, packing, site_log
from repro.core import quantization as Q
from repro.kernels import ops as K_ops
from repro.kernels import ref
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine, serve_sequential

RNG = np.random.default_rng(20251008)


def _with_override(cfg, site, backend):
    quant = dataclasses.replace(
        cfg.quant,
        backend_overrides=cfg.quant.backend_overrides + ((site, backend),),
    )
    return dataclasses.replace(cfg, quant=quant)


def _bit_planes(b, heads, s, dh, seed):
    """Random {0,1} bits packed to uint32 planes: (B, heads, S, dw)."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(b, heads, s, dh)).astype(np.uint32)
    return np.asarray(packing.pack_bits(jnp.asarray(bits), 1, axis=-1)), bits


# ---------------------------------------------------------------------------
# 1. kernel parity: every scores core vs the NumPy oracle, bit-exact
# ---------------------------------------------------------------------------

# (B, H, G, S, T, dh): square, ragged odd S + dh%32 != 0 + GQA, chunked T
# (T > kernels.binary_attn._T_CHUNK), and decode-shaped S=1 with dw=2
PARITY_SHAPES = [
    (1, 4, 4, 8, 8, 32),
    (2, 4, 2, 5, 7, 48),
    (1, 8, 2, 3, 300, 16),
    (2, 6, 3, 1, 9, 64),
]


def _scores_family():
    return backend_registry.backend_names(family="scores")


def test_scores_family_is_registered():
    names = _scores_family()
    assert "binary" in names and "float" in names and "mxu" in names
    # and the qmm family did not grow: scores-only backends are invisible
    # to QE.qmm and everything enumerating it
    assert set(backend_registry.backend_names(family="qmm")) == {
        "mxu", "popcount", "pallas", "fused",
    }


@pytest.mark.parametrize("backend", _scores_family())
@pytest.mark.parametrize("shape", PARITY_SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_scores_core_bit_exact_vs_oracle(backend, shape):
    b, h, g, s, t, dh = shape
    q_planes, _ = _bit_planes(b, h, s, dh, seed=hash(shape) % 2**31)
    k_planes, _ = _bit_planes(b, g, t, dh, seed=hash(shape) % 2**31 + 1)
    expect = ref.binary_attn_scores_ref(q_planes, k_planes, dh)
    out = K_ops.binary_attn_scores(
        jnp.asarray(q_planes), jnp.asarray(k_planes), dh=dh, backend=backend
    )
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), expect)


def test_scores_auto_dispatch_bit_exact():
    """The autotuned path ("auto" over the scores candidates) is numerically
    indistinguishable from any pinned core — dispatch never changes bits."""
    b, h, g, s, t, dh = 2, 4, 2, 6, 11, 48
    q_planes, _ = _bit_planes(b, h, s, dh, seed=7)
    k_planes, _ = _bit_planes(b, g, t, dh, seed=8)
    expect = ref.binary_attn_scores_ref(q_planes, k_planes, dh)
    out = K_ops.binary_attn_scores(
        jnp.asarray(q_planes), jnp.asarray(k_planes), dh=dh, backend="auto"
    )
    np.testing.assert_array_equal(np.asarray(out), expect)


def test_scores_core_rejects_malformed_operands():
    good, _ = _bit_planes(1, 2, 4, 32, seed=3)
    with pytest.raises(TypeError):
        K_ops.binary_attn_scores(
            jnp.asarray(good, jnp.int32), jnp.asarray(good), dh=32,
            backend="binary",
        )
    with pytest.raises(ValueError):  # word count inconsistent with dh
        K_ops.binary_attn_scores(
            jnp.asarray(good), jnp.asarray(good), dh=64, backend="binary"
        )
    with pytest.raises(ValueError):  # H not a multiple of G
        bad_k, _ = _bit_planes(1, 3, 4, 32, seed=4)
        K_ops.binary_attn_scores(
            jnp.asarray(good), jnp.asarray(bad_k), dh=32, backend="binary"
        )
    with pytest.raises(ValueError):  # qmm-family name is not a scores core
        K_ops.binary_attn_scores(
            jnp.asarray(good), jnp.asarray(good), dh=32, backend="fused"
        )


def test_qmm_rejects_scores_only_backend():
    xq = Q.quantize_activation(jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32), 8)
    yq = Q.quantize_activation(jnp.asarray(RNG.standard_normal((32, 4)), jnp.float32), 8)
    from repro.core import qmm as QE

    with pytest.raises(ValueError, match="families"):
        QE.qmm(xq, yq, backend="float")


# ---------------------------------------------------------------------------
# 2. engine differential: binary engagement vs the float-score oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bitbert():
    cfg = smoke_variant(get_config("bit-bert-base"))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    return cfg, serving


def _requests(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(
                0, cfg.vocab_size, size=(int(rng.integers(3, 11)),)
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(3, 7)),
        )
        for _ in range(n)
    ]


def test_binary_cache_layout(bitbert):
    """Engaging attn.qk -> binary shrinks the K cache to packed uint32
    planes (dh bits per row instead of dh int8 bytes); V stays int8."""
    cfg, _ = bitbert
    cfgb = _with_override(cfg, "attn.qk", "binary")
    cache = jax.eval_shape(lambda: Z.init_cache(1, 32, cfgb))
    base = jax.eval_shape(lambda: Z.init_cache(1, 32, cfg))
    leaves = {
        jax.tree_util.keystr(kp): leaf
        for kp, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]
    }
    k = next(v for p, v in leaves.items() if p.endswith("['k']"))
    v = next(v for p, v in leaves.items() if p.endswith("['v']"))
    base_leaves = {
        jax.tree_util.keystr(kp): leaf
        for kp, leaf in jax.tree_util.tree_flatten_with_path(base)[0]
    }
    k_int8 = next(v for p, v in base_leaves.items() if p.endswith("['k']"))
    assert k_int8.dtype == jnp.int8
    assert k.dtype == jnp.uint32
    assert v.dtype == jnp.int8
    assert k.shape[-1] == packing.packed_len(cfg.d_head, 1)
    # 32 bits of storage per 16-bit-dh row vs 16 int8 bytes: 4x here, up to
    # 8x at dh=256 — the serve-mode KV shrink the family buys
    assert k.size * 4 < v.size


def test_sequential_binary_matches_float_oracle(bitbert):
    """THE differential: greedy serving with the autotuned binary engagement
    == the pinned float score core, token for token (all scores cores are
    bit-exact, and the affine epilogue is shared caller code)."""
    cfg, serving = bitbert
    cfgb = _with_override(cfg, "attn.qk", "binary")
    cfgf = _with_override(cfg, "attn.qk", "float")
    outs = {}
    for tag, c in (("binary", cfgb), ("float", cfgf)):
        done = serve_sequential(c, serving, _requests(cfg), max_len=32, seed=0)
        outs[tag] = [r.output for r in done]
    assert outs["binary"] == outs["float"]
    assert all(len(o) for o in outs["binary"])


def test_engine_binary_matches_sequential_oracle(bitbert):
    """Slot-managed continuous batching with the binary engagement matches
    the sequential oracle exactly — scheduling stays numerically invisible
    through the packed-plane cache (per-row binarization grids)."""
    cfg, serving = bitbert
    cfgb = _with_override(cfg, "attn.qk", "binary")
    eng = ServeEngine(cfgb, serving, batch_slots=2, max_len=48, seed=0)
    reqs = _requests(cfg, n=5, seed=1)
    got = {id(r): r.output for r in eng.run(reqs)}
    expect = serve_sequential(cfgb, serving, _requests(cfg, n=5, seed=1),
                              max_len=48, seed=0)
    assert sorted(got.values()) == sorted(r.output for r in expect)


def test_binary_differs_from_int8_path(bitbert):
    """Sanity that the differential is not vacuous: the 1-bit score path is
    a genuinely different quantization than the int8 act x act path."""
    cfg, serving = bitbert
    cfgb = _with_override(cfg, "attn.qk", "binary")
    a = [r.output for r in serve_sequential(cfgb, serving, _requests(cfg),
                                            max_len=32, seed=0)]
    b = [r.output for r in serve_sequential(cfg, serving, _requests(cfg),
                                            max_len=32, seed=0)]
    assert a != b


def test_stale_cache_rows_are_invisible(bitbert):
    """Masked positions must not read the packed K rows beyond the cursor:
    corrupting them with garbage leaves decode logits bit-identical."""
    cfg, serving = bitbert
    cfgb = _with_override(cfg, "attn.qk", "binary")
    prompt = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(1, 6)), jnp.int32)
    cache = Z.init_cache(1, 24, cfgb)
    _, cache = Z.prefill(serving, prompt, cfgb, cache)
    tok = jnp.asarray([5], jnp.int32)

    def corrupt(leaf):
        if leaf.dtype == jnp.uint32 and leaf.ndim >= 4:  # packed K planes
            garbage = jnp.asarray(
                RNG.integers(0, 2**32, size=leaf.shape, dtype=np.uint64)
                .astype(np.uint32)
            )
            # rows at positions >= 7 (prompt 6 + 1 decode write) are dead
            mask = jnp.arange(leaf.shape[-3])[None, :, None, None] >= 7
            return jnp.where(mask, garbage, leaf)
        return leaf

    la, _ = Z.decode_step(serving, tok, cfgb, cache)
    lb, _ = Z.decode_step(serving, tok, cfgb, jax.tree.map(corrupt, cache))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# 3. site semantics: attn.qk and attn.qk_latent are separate addresses
# ---------------------------------------------------------------------------


def _mla_decode_sites(cfg):
    serving = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    cache = jax.eval_shape(lambda: Z.init_cache(2, 16, cfg))
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    with site_log.recording() as sites:
        jax.make_jaxpr(lambda p, t, c: Z.decode_step(p, t, cfg, c))(
            serving, tok, cache
        )
    return [s for s in sites if s.get("kind") == "attn"]


def test_qk_override_does_not_reach_latent_site():
    """Regression for the latent-site asymmetry: ``attn.qk`` overrides are
    NOT wildcards over ``attn.qk_latent`` — the MLA latent QMM keeps its own
    address and stays on the int path until ITS site is overridden."""
    base = smoke_variant(get_config("deepseek-v2-lite-16b"))
    sites = _mla_decode_sites(_with_override(base, "attn.qk", "binary"))
    latent = [s for s in sites if s.get("site") == "attn.qk_latent"]
    assert latent, "MLA decode recorded no latent site"
    for s in latent:
        # still the int path: the recorded backend is the site's resolved
        # name (config default), never the scores-only engagement
        assert s.get("backend") != "binary"
        assert s.get("bits") == base.quant.attn_act_bits
        assert s.get("mantissa_dtype") == "int8"


def test_latent_site_engages_via_its_own_override():
    """The satellite-3 unification: attn.qk_latent is reachable through
    backend_for overrides just like attn.qk."""
    base = smoke_variant(get_config("deepseek-v2-lite-16b"))
    sites = _mla_decode_sites(_with_override(base, "attn.qk_latent", "binary"))
    latent = [s for s in sites if s.get("site") == "attn.qk_latent"]
    assert latent, "MLA decode recorded no latent site"
    for s in latent:
        assert s.get("backend") == "binary"
        assert s.get("bits") == 1
        assert s.get("mantissa_dtype") == "uint8"


def test_latent_binary_decode_runs_concrete():
    """The latent binary path executes (not just traces): greedy decode on
    the MLA arch with attn.qk_latent -> binary produces valid tokens."""
    cfg = _with_override(
        smoke_variant(get_config("deepseek-v2-lite-16b")), "attn.qk_latent",
        "binary",
    )
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    done = serve_sequential(cfg, serving, _requests(cfg, n=2), max_len=24,
                            seed=0)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.output)
