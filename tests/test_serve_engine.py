"""Serving engine + dry-run helper units (fast, no big compiles)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES_BY_NAME
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_variant(get_config("granite-8b"))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    return cfg, ServeEngine(cfg, serving, batch_slots=2, max_len=48, seed=0)


def test_engine_serves_a_queue(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=(5 + i,)).astype(np.int32),
                max_new_tokens=6)
        for i in range(5)  # 5 requests through 2 slots -> 3 waves
    ]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.output)


def test_greedy_is_deterministic(engine):
    cfg, eng = engine
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    a = eng.run([Request(prompt=prompt, max_new_tokens=5)])[0].output
    b = eng.run([Request(prompt=prompt, max_new_tokens=5)])[0].output
    assert a == b


def test_temperature_sampling_varies(engine):
    cfg, eng = engine
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    outs = {
        tuple(eng.run([Request(prompt=prompt, max_new_tokens=8, temperature=1.5)])[0].output)
        for _ in range(3)
    }
    assert len(outs) > 1  # overwhelmingly likely with T=1.5


# ---------------------------------------------------------------------------
# dry-run helper units
# ---------------------------------------------------------------------------


def test_collective_byte_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
      %ag = f32[128,256]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%sum
      %nothing = f32[2,2]{1,0} add(%a, %b)
      %aa = (s8[16,16]{1,0}, s8[16,16]{1,0}) all-to-all(%p, %q)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"]["bytes"] == 128 * 256 * 4
    assert out["all-gather"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 64 * 2
    assert out["all-to-all"]["bytes"] == 2 * 16 * 16
    assert out["total_bytes"] == 128 * 256 * 4 + 128 + 512


def test_skip_rules_match_design_doc():
    from repro.launch.dryrun import skip_reason

    long = SHAPES_BY_NAME["long_500k"]
    assert skip_reason(get_config("mistral-nemo-12b"), long)  # full attention
    assert skip_reason(get_config("gemma3-27b"), long)  # has global layers
    assert skip_reason(get_config("mamba2-130m"), long) is None  # SSM runs
    assert skip_reason(get_config("recurrentgemma-2b"), long) is None  # hybrid runs
    train = SHAPES_BY_NAME["train_4k"]
    for arch in ("granite-8b", "deepseek-v3-671b", "whisper-tiny"):
        assert skip_reason(get_config(arch), train) is None


def test_input_specs_cover_frontends():
    from repro.launch.dryrun import input_specs

    shape = SHAPES_BY_NAME["train_4k"]
    s1 = input_specs(get_config("granite-8b"), shape)
    assert set(s1) == {"tokens"} and s1["tokens"].shape == (256, 4096)
    s2 = input_specs(get_config("whisper-tiny"), shape)
    assert s2["frontend"].shape == (256, 1500, 384)
    s3 = input_specs(get_config("internvl2-2b"), shape)
    assert s3["frontend"].shape == (256, 256, 1024)


def test_opt_transforms_apply():
    from repro.launch.dryrun import apply_opts

    cfg = get_config("granite-8b")
    out = apply_opts(cfg, ["scores_bf16", "gqa_expand", "packed_gather"])
    assert out.attn_scores_dtype == "bf16"
    assert out.gqa_mode == "expand"
    assert out.quant.prebinarize_gather
