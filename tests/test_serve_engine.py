"""Serving engine + dry-run helper units (fast, no big compiles)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES_BY_NAME
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine, serve_sequential


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_variant(get_config("granite-8b"))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    return cfg, ServeEngine(cfg, serving, batch_slots=2, max_len=48, seed=0)


def _mixed_requests(cfg, n=5, seed=0, max_new=(3, 7), plen=(3, 11)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(
                0, cfg.vocab_size, size=(int(rng.integers(*plen)),)
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for _ in range(n)
    ]


def test_engine_serves_a_queue(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=(5 + i,)).astype(np.int32),
                max_new_tokens=6)
        for i in range(5)  # 5 requests through 2 slots -> 3 waves
    ]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.output)


def test_greedy_is_deterministic(engine):
    cfg, eng = engine
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    a = eng.run([Request(prompt=prompt, max_new_tokens=5)])[0].output
    b = eng.run([Request(prompt=prompt, max_new_tokens=5)])[0].output
    assert a == b


def test_temperature_sampling_varies(engine):
    cfg, eng = engine
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    outs = {
        tuple(eng.run([Request(prompt=prompt, max_new_tokens=8, temperature=1.5)])[0].output)
        for _ in range(3)
    }
    assert len(outs) > 1  # overwhelmingly likely with T=1.5


# ---------------------------------------------------------------------------
# differential: continuous batching vs the one-request-at-a-time oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-130m"])
def test_differential_greedy_matches_oracle(arch):
    """THE serving-correctness guarantee: slot-managed continuous batching
    (mixed-length requests co-scheduled in one packed decode batch) produces
    exactly the tokens the naive sequential loop produces — scheduling is
    numerically invisible (per-row cache state + per-token quantization)."""
    cfg = smoke_variant(get_config(arch))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    reqs = _mixed_requests(cfg, n=5, seed=42)
    oracle = serve_sequential(
        cfg, serving, _mixed_requests(cfg, n=5, seed=42), max_len=48, seed=0
    )
    eng = ServeEngine(cfg, serving, batch_slots=2, max_len=48, seed=0)
    done = eng.run(reqs)
    for got, want in zip(done, oracle):
        assert got.output == want.output, (
            f"{arch}: engine diverged from oracle "
            f"(prompt_len={len(got.prompt)}): {got.output} != {want.output}"
        )


def test_differential_invariant_to_arrivals(engine):
    """Outputs must not depend on WHEN requests arrive (open-loop traffic):
    staggered admission only changes the schedule, never the tokens."""
    cfg, eng = engine
    a = eng.run(_mixed_requests(cfg, n=4, seed=7))
    staggered = _mixed_requests(cfg, n=4, seed=7)
    for i, r in enumerate(staggered):
        r.arrival_s = 0.05 * i
    b = eng.run(staggered)
    assert [r.output for r in a] == [r.output for r in b]


def test_streaming_callbacks_and_timing(engine):
    cfg, eng = engine
    seen = []
    reqs = _mixed_requests(cfg, n=3, seed=3)
    for i, r in enumerate(reqs):
        r.on_token = lambda tok, i=i: seen.append((i, tok))
    done = eng.run(reqs)
    for i, r in enumerate(done):
        assert [t for j, t in seen if j == i] == r.output  # streamed == final
        assert len(r.token_times) == r.max_new_tokens
        assert r.t_admitted is not None and r.t_first_token is not None
        assert r.t_admitted <= r.t_first_token <= r.t_finished
        assert r.token_times == sorted(r.token_times)


def test_request_validation(engine):
    cfg, eng = engine
    big = Request(prompt=np.zeros((40,), np.int32), max_new_tokens=20)  # 60 > 48
    with pytest.raises(ValueError):
        eng.run([big])
    empty = Request(prompt=np.zeros((0,), np.int32), max_new_tokens=2)
    with pytest.raises(ValueError):
        eng.run([empty])


def test_event_trace_records_slot_lifecycle(engine):
    cfg, eng = engine
    done = eng.run(_mixed_requests(cfg, n=3, seed=9))
    kinds = [e["kind"] for e in eng.last_events]
    for k in ("admit", "prefill", "insert", "decode_tick", "finish", "reset"):
        assert k in kinds
    admits = [e for e in eng.last_events if e["kind"] == "admit"]
    finishes = [e for e in eng.last_events if e["kind"] == "finish"]
    assert {e["rid"] for e in admits} == {r.rid for r in done}
    assert {e["rid"] for e in finishes} == {r.rid for r in done}


# ---------------------------------------------------------------------------
# dry-run helper units
# ---------------------------------------------------------------------------


def test_collective_byte_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
      %ag = f32[128,256]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%sum
      %nothing = f32[2,2]{1,0} add(%a, %b)
      %aa = (s8[16,16]{1,0}, s8[16,16]{1,0}) all-to-all(%p, %q)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"]["bytes"] == 128 * 256 * 4
    assert out["all-gather"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 64 * 2
    assert out["all-to-all"]["bytes"] == 2 * 16 * 16
    assert out["total_bytes"] == 128 * 256 * 4 + 128 + 512


def test_skip_rules_match_design_doc():
    from repro.launch.dryrun import skip_reason

    long = SHAPES_BY_NAME["long_500k"]
    assert skip_reason(get_config("mistral-nemo-12b"), long)  # full attention
    assert skip_reason(get_config("gemma3-27b"), long)  # has global layers
    assert skip_reason(get_config("mamba2-130m"), long) is None  # SSM runs
    assert skip_reason(get_config("recurrentgemma-2b"), long) is None  # hybrid runs
    train = SHAPES_BY_NAME["train_4k"]
    for arch in ("granite-8b", "deepseek-v3-671b", "whisper-tiny"):
        assert skip_reason(get_config(arch), train) is None


def test_input_specs_cover_frontends():
    from repro.launch.dryrun import input_specs

    shape = SHAPES_BY_NAME["train_4k"]
    s1 = input_specs(get_config("granite-8b"), shape)
    assert set(s1) == {"tokens"} and s1["tokens"].shape == (256, 4096)
    s2 = input_specs(get_config("whisper-tiny"), shape)
    assert s2["frontend"].shape == (256, 1500, 384)
    s3 = input_specs(get_config("internvl2-2b"), shape)
    assert s3["frontend"].shape == (256, 256, 1024)


def test_opt_transforms_apply():
    from repro.launch.dryrun import apply_opts

    cfg = get_config("granite-8b")
    out = apply_opts(cfg, ["scores_bf16", "gqa_expand", "packed_gather"])
    assert out.attn_scores_dtype == "bf16"
    assert out.gqa_mode == "expand"
    assert out.quant.prebinarize_gather
