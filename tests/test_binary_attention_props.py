"""Property-based invariants of the bitwise-attention path (hypothesis).

Optional-dep module (the test_serve_slots.py idiom): gated by importorskip
so the minimal CI matrix exercises its absence.  Properties:

* elastic 1-bit binarization is monotone (order -> bit order) and exactly
  equivariant under positive power-of-two scaling of the row;
* AND-popcount scores are self-similar: ``counts(a, a)`` is symmetric with
  ``rowsum(a)`` on the diagonal — the {0,1}-domain analogue of the XNOR
  identity ``xnor_popcount(a, a) == K``.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional test dep; gate, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core import packing
from repro.core import quantization as Q
from repro.kernels import ops as K_ops


def _bit_planes(b, heads, s, dh, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(b, heads, s, dh)).astype(np.uint32)
    return np.asarray(packing.pack_bits(jnp.asarray(bits), 1, axis=-1)), bits


_rows = st.lists(
    st.floats(-8.0, 8.0, allow_nan=False, width=32), min_size=4, max_size=32
)


@settings(max_examples=20, deadline=None)
@given(_rows)
def test_binarization_is_monotone(row):
    """Elastic binarization preserves order: x_i <= x_j => bit_i <= bit_j
    (the sign structure of the row survives 1-bit quantization)."""
    x = jnp.asarray(row, jnp.float32)[None, :]
    bits = np.asarray(Q.quantize_activation(x, 1, per_channel_axis=0).mantissa[0])
    order = np.argsort(np.asarray(row), kind="stable")
    assert (np.diff(bits[order]) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(_rows, st.integers(-4, 4))
def test_binarization_scale_equivariance(row, log2c):
    """Scaling a row by a positive power of two leaves the mantissa bits
    unchanged and scales the affine exactly (no regrid drift)."""
    c = float(2.0 ** log2c)
    x = jnp.asarray(row, jnp.float32)[None, :]
    a = Q.quantize_activation(x, 1, per_channel_axis=0)
    b = Q.quantize_activation(c * x, 1, per_channel_axis=0)
    np.testing.assert_array_equal(np.asarray(a.mantissa), np.asarray(b.mantissa))
    if float(jnp.max(x)) > float(jnp.min(x)):  # non-degenerate grid
        np.testing.assert_allclose(
            np.asarray(b.scale), c * np.asarray(a.scale), rtol=1e-6
        )


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 6))
def test_popcount_self_similarity(seed, heads, s):
    """counts(a, a) has rowsum(a) on its diagonal and is symmetric — the
    AND-popcount analogue of the XNOR identity xnor_pop(a, a) == K."""
    dh = 40
    planes, bits = _bit_planes(1, heads, s, dh, seed=seed)
    counts = np.asarray(
        K_ops.binary_attn_scores(
            jnp.asarray(planes), jnp.asarray(planes), dh=dh, backend="binary"
        )
    )
    rowsum = bits.sum(-1)
    for hh in range(heads):
        np.testing.assert_array_equal(np.diagonal(counts[0, hh]), rowsum[0, hh])
        np.testing.assert_array_equal(counts[0, hh], counts[0, hh].T)
