"""BENCH_qmm schema + analytical roofline cells (no wall-clock timing here:
CI's roofline smoke cell covers the measured path end-to-end)."""

import os

import pytest

from repro.core import backend_registry as BR
from repro.core import qmm_roofline as R

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _fake_doc(backends=None):
    cells = [
        dict(
            R.cell_model(b, 8, 128, 128, 1, 1),
            measured_us=1.0,
        )
        for b in (backends or BR.backend_names())
    ]
    return {
        "schema": R.SCHEMA,
        "generated_unix": 0.0,
        "platform": "cpu",
        "hardware": {"hbm_bw": R.HBM_BW, "peak_int_ops": R.PEAK_INT_OPS},
        "backends": [c["backend"] for c in cells],
        "cells": cells,
    }


def test_cell_model_uses_registry_traffic_models():
    """The fused kernel's modeled traffic must undercut the staged pallas
    path (the int32 MM round-trip is the whole point of fusing) and every
    cell carries both roofs."""
    shape = (64, 512, 512)
    fused = R.cell_model("fused", *shape, 1, 1)
    staged = R.cell_model("pallas", *shape, 1, 1)
    assert fused["bytes"] < staged["bytes"]
    assert fused["intensity"] > staged["intensity"]
    for c in (fused, staged):
        assert c["roof_us"] == max(c["t_compute_us"], c["t_memory_us"])
        assert c["bound"] in ("compute", "memory")


def test_cell_model_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        R.cell_model("fpga", 8, 64, 64, 1, 1)


def test_validate_accepts_complete_doc():
    assert R.validate_qmm_bench(_fake_doc()) is not None


def test_validate_rejects_schema_and_shape_violations():
    doc = _fake_doc()
    with pytest.raises(ValueError, match="schema mismatch"):
        R.validate_qmm_bench(dict(doc, schema="qmm-roofline/v0"))
    with pytest.raises(ValueError, match="non-empty"):
        R.validate_qmm_bench(dict(doc, cells=[]))
    broken = _fake_doc()
    del broken["cells"][0]["bytes"]
    with pytest.raises(ValueError, match="'bytes' must be numeric"):
        R.validate_qmm_bench(broken)


def test_validate_rejects_stale_artifact_missing_a_registered_backend():
    """Adding a backend without re-recording BENCH_qmm.json must fail —
    the artifact claims roofline placements for the whole registry."""
    partial = _fake_doc(backends=[n for n in BR.backend_names() if n != "fused"])
    with pytest.raises(ValueError, match="stale.*fused"):
        R.validate_qmm_bench(partial)


def test_committed_artifact_validates_against_current_registry():
    path = os.path.join(REPO, "BENCH_qmm.json")
    doc = R.load_qmm_bench(path)
    covered = {c["backend"] for c in doc["cells"]}
    # the QMM roofline tracks the qmm family; scores-family backends are
    # tracked by BENCH_attn.json instead
    assert covered >= set(BR.backend_names(family="qmm"))
