"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Each kernel is swept over shapes (block-aligned and ragged via the ops
wrappers) and operand widths, asserting bit-exact integer agreement with
ref.py, plus end-to-end QuantTensor dispatch against the dequantized oracle.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import flow_abstraction as FA
from repro.core import packing
from repro.core import quantization as Q
from repro.kernels import binary_qmm as BK
from repro.kernels import bitserial_qmm as BS
from repro.kernels import popcount_qmm as PK
from repro.kernels import ops, ref


RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# binary_qmm: fused unpack -> MXU int8 dot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n",
    [(128, 512, 128), (256, 512, 128), (128, 1024, 256)],
)
def test_binary_qmm_block_aligned(m, k, n):
    a = RNG.integers(-128, 128, size=(m, k)).astype(np.int8)
    w = RNG.integers(0, 2, size=(k, n)).astype(np.int32)
    wp = packing.pack_bits(jnp.asarray(w), 1, axis=0)
    out = BK.binary_qmm(jnp.asarray(a), wp, k=k, interpret=True)
    expect = ref.binary_qmm_ref(jnp.asarray(a), wp, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    np.testing.assert_array_equal(np.asarray(out), a.astype(np.int64) @ w)


@pytest.mark.parametrize("m,k,n", [(1, 32, 1), (37, 300, 45), (130, 513, 129)])
def test_binary_qmm_ragged_via_ops(m, k, n):
    """ops wrapper pads ragged shapes; zero-padding must be exact."""
    a = RNG.integers(-8, 8, size=(m, k)).astype(np.int8)
    w = RNG.integers(0, 2, size=(k, n)).astype(np.int32)
    wp = packing.pack_bits(jnp.asarray(w), 1, axis=0)
    out = ops.binary_qmm_int(jnp.asarray(a), wp, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), a.astype(np.int64) @ w)


def test_binary_qmm_rejects_bad_shapes():
    a = jnp.zeros((64, 512), jnp.int8)
    wp = jnp.zeros((16, 128), jnp.uint32)
    with pytest.raises(ValueError):
        BK.binary_qmm(a, wp, k=512, interpret=True)  # 64 % 128 != 0


# ---------------------------------------------------------------------------
# popcount_qmm: the DPU analogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(64, 2048, 128), (128, 4096, 256)])
def test_popcount_qmm_block_aligned(m, k, n):
    a = RNG.integers(0, 2, size=(m, k)).astype(np.int32)
    b = RNG.integers(0, 2, size=(k, n)).astype(np.int32)
    ap = packing.pack_bits(jnp.asarray(a), 1, axis=-1)
    bp = packing.pack_bits(jnp.asarray(b), 1, axis=0)
    out = PK.popcount_qmm(ap, bp, interpret=True)
    expect = ref.popcount_qmm_ref(ap, bp, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    np.testing.assert_array_equal(np.asarray(out), a @ b)


@pytest.mark.parametrize("m,k,n", [(5, 64, 3), (70, 1000, 140)])
def test_popcount_qmm_ragged_via_ops(m, k, n):
    a = RNG.integers(0, 2, size=(m, k)).astype(np.int32)
    b = RNG.integers(0, 2, size=(k, n)).astype(np.int32)
    ap = packing.pack_bits(jnp.asarray(a), 1, axis=-1)
    bp = packing.pack_bits(jnp.asarray(b), 1, axis=0)
    out = ops.popcount_qmm_int(ap, bp, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), a @ b)


# ---------------------------------------------------------------------------
# bitserial_qmm: multi-bit act x act
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a_bits,b_bits", [(2, 2), (4, 4), (4, 8), (8, 8)])
def test_bitserial_qmm_block_aligned(a_bits, b_bits):
    m, k, n = 64, 1024, 128
    a = RNG.integers(0, 2**a_bits, size=(m, k)).astype(np.int32)
    b = RNG.integers(0, 2**b_bits, size=(k, n)).astype(np.int32)
    apl = packing.pack_bitplanes(jnp.asarray(a), a_bits, axis=-1)
    bpl = packing.pack_bitplanes(jnp.asarray(b), b_bits, axis=-2)
    out = BS.bitserial_qmm(apl, bpl, interpret=True)
    expect = ref.bitserial_qmm_ref(apl, bpl, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    np.testing.assert_array_equal(np.asarray(out), a @ b)


def test_bitserial_qmm_ragged_via_ops():
    m, k, n = 33, 190, 77
    a = RNG.integers(0, 16, size=(m, k)).astype(np.int32)
    b = RNG.integers(0, 16, size=(k, n)).astype(np.int32)
    apl = packing.pack_bitplanes(jnp.asarray(a), 4, axis=-1)
    bpl = packing.pack_bitplanes(jnp.asarray(b), 4, axis=-2)
    out = ops.bitserial_qmm_int(apl, bpl, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), a @ b)


# ---------------------------------------------------------------------------
# end-to-end dispatch: QuantTensor in, flow-abstraction epilogue out
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act_bits", [1, 2, 4, 8])
def test_qmm_pallas_act_weight_matches_oracle(act_bits):
    x = jnp.asarray(RNG.normal(size=(37, 300)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(300, 45)).astype(np.float32))
    xq = Q.quantize_activation(x, act_bits)
    wq = Q.binarize_weight(w)
    expect = FA.qmm_dequant_reference(xq, wq)
    out = ops.qmm_pallas(xq, wq, interpret=True)
    tol = 3e-5 * max(1.0, float(jnp.max(jnp.abs(expect))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=tol)


@pytest.mark.parametrize("act_bits", [2, 4, 8])
def test_qmm_pallas_act_act_matches_oracle(act_bits):
    a = jnp.asarray(RNG.normal(size=(20, 75)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(75, 30)).astype(np.float32))
    aq = Q.quantize_activation(a, act_bits)
    bq = Q.quantize_activation(b, act_bits)
    expect = FA.qmm_dequant_reference(aq, bq)
    out = ops.qmm_pallas(aq, bq, interpret=True)
    tol = 3e-4 * max(1.0, float(jnp.max(jnp.abs(expect))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=tol)


def test_qmm_pallas_packed_weights():
    """Serving layout: weights arrive packed from the checkpoint."""
    x = jnp.asarray(RNG.normal(size=(16, 256)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(256, 64)).astype(np.float32))
    xq = Q.quantize_activation(x, 4)
    wq = Q.binarize_weight(w).pack(axis=0)
    expect = FA.qmm_dequant_reference(Q.quantize_activation(x, 4), Q.binarize_weight(w))
    out = ops.qmm_pallas(xq, wq, interpret=True)
    tol = 3e-5 * max(1.0, float(jnp.max(jnp.abs(expect))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=tol)


def test_qmm_pallas_agrees_with_mxu_backend():
    from repro.core import qmm as QE

    x = jnp.asarray(RNG.normal(size=(24, 200)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(200, 40)).astype(np.float32))
    xq = Q.quantize_activation(x, 4)
    wq = Q.binarize_weight(w)
    a = QE.qmm(xq, wq, backend="mxu")
    b = ops.qmm_pallas(xq, wq, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)
