"""int8 error-feedback gradient compression: unit + multi-device parity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional test dep; gate, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.optim import compression as C


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compress_decompress_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(257).astype(np.float32) * 10.0)
    e = jnp.zeros_like(g)
    q, s, resid = C.compress(g, e)
    assert q.dtype == jnp.int8
    deq = C.decompress(q, s)
    # quantization error bounded by half a step
    np.testing.assert_allclose(np.asarray(deq), np.asarray(g), atol=float(s) * 0.51)
    np.testing.assert_allclose(np.asarray(g - deq), np.asarray(resid), rtol=1e-5, atol=1e-6)


def test_error_feedback_corrects_bias():
    """With a CONSTANT gradient, error feedback must make the time-average
    of the dequantized stream converge to the true gradient."""
    g = jnp.asarray(np.linspace(-3, 3, 64).astype(np.float32) + 0.017)
    e = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, s, e = C.compress(g, e)
        acc = acc + C.decompress(q, s)
    avg = acc / n
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g), atol=2e-3)


def test_residual_norm_stays_bounded():
    rng = np.random.default_rng(0)
    e = jnp.zeros(1024)
    norms = []
    for i in range(20):
        g = jnp.asarray(rng.standard_normal(1024).astype(np.float32))
        q, s, e = C.compress(g, e)
        norms.append(float(jnp.linalg.norm(e)))
    assert max(norms[5:]) < 10 * min(norms[5:]) + 1.0  # no blow-up


def test_compressed_psum_multidevice_parity():
    """8 virtual devices: compressed all-reduce ~= exact fp32 mean."""
    import subprocess, sys, os, textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim import compression as C

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 128)).astype(np.float32))

        def f(g):
            g = g[0]
            err = {"g": jnp.zeros_like(g)}
            avg, err = C.compressed_psum({"g": g}, err, "data")
            exact, _ = C.compressed_psum({"g": g}, err, "data", enabled=False)
            return avg["g"][None], exact["g"][None]

        avg, exact = shard_map(
            f, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
            check_rep=False
        )(g)
        a, e = np.asarray(avg[0]), np.asarray(exact[0])
        rel = np.abs(a - e).max() / (np.abs(e).max() + 1e-9)
        assert rel < 0.02, rel
        print("PARITY_OK", rel)
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=300, cwd=os.getcwd(),
    )
    assert "PARITY_OK" in out.stdout, out.stdout + out.stderr
