"""Autotuned QMM dispatch: keying, persistence, overrides, backend parity.

The fake-timer tests determinize the "which backend wins" question (the
timer is injectable); the real-timer test asserts internal consistency and
the one measured fact that is robust on any host: at a large-M 1-bit x
1-bit shape the packed popcount path beats unpacking for the MXU path by a
wide margin, so ``backend="auto"`` must select a non-mxu backend there.
"""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import QuantConfig
from repro.core import dispatch
from repro.core import flow_abstraction as FA
from repro.core import packing
from repro.core import qmm as QE
from repro.core import quantization as Q
from repro.kernels import ref

RNG = np.random.default_rng(99)


@pytest.fixture(autouse=True)
def _fresh_default_cache():
    """Isolate the process-wide cache per test."""
    dispatch.reset_cache()
    yield
    dispatch.reset_cache()


def seq_timer(values):
    """Fake timer returning ``values`` in registry candidate order (mxu,
    popcount, then pallas/fused when eligible) — determinizes the winner."""
    it = iter(values)

    def timer(fn):
        return next(it)

    return timer


def _quant_pair(m, k, n, act_bits, weight_bits=1):
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((k, n)).astype(np.float32))
    xq = Q.quantize_activation(x, act_bits)
    wq = Q.quantize_weight(w, weight_bits)
    return xq, wq


# ---------------------------------------------------------------------------
# cache keying
# ---------------------------------------------------------------------------


def test_distinct_shapes_and_precisions_get_distinct_entries():
    cache = dispatch.AutotuneCache(timer=seq_timer([1.0] * 100))
    cache.choose(8, 64, 32, 1, 1)
    assert len(cache) == 1
    cache.choose(8, 64, 32, 1, 1)  # same key: served from cache
    assert len(cache) == 1
    cache.choose(8, 64, 64, 1, 1)  # different N
    cache.choose(8, 128, 32, 1, 1)  # different K
    cache.choose(8, 64, 32, 8, 1)  # different act precision
    cache.choose(1024, 64, 32, 1, 1)  # different M bucket
    assert len(cache) == 5


def test_repeat_lookup_does_not_retime():
    cache = dispatch.AutotuneCache(timer=seq_timer([1.0] * 10))
    cache.choose(8, 64, 32, 1, 1)
    runs = cache.timing_runs
    assert runs > 0
    for _ in range(5):
        cache.choose(8, 64, 32, 1, 1)
    assert cache.timing_runs == runs


def test_m_bucketing_shares_ragged_serving_waves():
    """Prompt lengths 100 and 128 share a bucket; 129 starts a new one."""
    cache = dispatch.AutotuneCache(timer=seq_timer([1.0] * 100))
    cache.choose(100, 64, 32, 1, 1)
    cache.choose(128, 64, 32, 1, 1)
    assert len(cache) == 1
    cache.choose(129, 64, 32, 1, 1)
    assert len(cache) == 2


def test_phase_tags_split_prefill_and_decode():
    cache = dispatch.AutotuneCache(timer=seq_timer([1.0] * 100))
    with dispatch.tuning_phase("prefill"):
        cache.choose(8, 64, 32, 1, 1)
    with dispatch.tuning_phase("decode"):
        cache.choose(8, 64, 32, 1, 1)
    assert len(cache) == 2
    assert dispatch.current_phase() == ""


def test_fake_timer_winner_is_recorded():
    # candidates at this tiny shape: (mxu, popcount, pallas, fused);
    # make popcount win
    cache = dispatch.AutotuneCache(timer=seq_timer([10.0, 1.0, 5.0, 7.0]))
    assert cache.choose(8, 64, 32, 1, 1) == "popcount"
    (rec,) = cache.entries.values()
    assert rec.timed and rec.backend == "popcount"
    assert rec.backend == min(rec.timings_us, key=rec.timings_us.get)


# ---------------------------------------------------------------------------
# the acceptance path: qmm(backend="auto") routes through the cache
# ---------------------------------------------------------------------------


def test_auto_routes_through_default_cache_and_matches_mxu():
    cache = dispatch.reset_cache(
        dispatch.AutotuneCache(timer=seq_timer([10.0, 1.0, 5.0, 7.0] * 10))
    )
    xq, wq = _quant_pair(16, 64, 32, 1)
    out = QE.qmm(xq, wq, backend="auto")
    assert len(cache) == 1
    (rec,) = cache.entries.values()
    assert rec.backend == "popcount"  # the fake-timed winner, not hardcoded mxu
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(QE.qmm(xq, wq, backend="mxu")),
        rtol=1e-5,
        atol=1e-4,
    )


def test_real_timing_selects_non_mxu_for_large_binary_qmm():
    """W1A1 at M=256: packed AND+popcount skips the unpack the MXU path
    pays; the measured winner is consistently non-mxu off-TPU (~8x margin
    on CPU).  On TPU the MXU can legitimately win, so skip there."""
    from repro.kernels import ops

    if ops.on_tpu():
        pytest.skip("off-TPU measurement claim; MXU may win on TPU")
    cache = dispatch.AutotuneCache()
    chosen = cache.choose(256, 768, 768, 1, 1)
    (rec,) = cache.entries.values()
    # internal consistency: the recorded winner is the argmin of its timings
    assert chosen == min(rec.timings_us, key=rec.timings_us.get)
    assert chosen != "mxu"


def test_auto_works_under_jit():
    cache = dispatch.reset_cache(
        dispatch.AutotuneCache(timer=seq_timer([1.0] * 100))
    )
    xq, wq = _quant_pair(16, 64, 32, 4)

    fn = jax.jit(lambda a, b: QE.qmm(a, b, backend="auto"))
    out = fn(xq, wq)
    assert len(cache) >= 1  # tuned once, at trace time
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(QE.qmm(xq, wq, backend="mxu")),
        rtol=1e-5,
        atol=1e-4,
    )


def test_env_kill_switch_disables_tuning(monkeypatch):
    monkeypatch.setenv("REPRO_QMM_AUTOTUNE", "0")
    cache = dispatch.reset_cache(
        dispatch.AutotuneCache(timer=seq_timer([1.0] * 10))
    )
    assert dispatch.choose_backend(8, 64, 32, 1, 1) == dispatch.DEFAULT_BACKEND
    assert len(cache) == 0 and cache.timing_runs == 0


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def test_persist_reload_round_trip_skips_retiming(tmp_path):
    path = str(tmp_path / "autotune.json")
    cache = dispatch.AutotuneCache(timer=seq_timer([3.0, 1.0, 2.0, 4.0] * 10))
    first = cache.choose(8, 64, 32, 1, 1)
    cache.choose(8, 64, 64, 8, 1, tag="decode")
    cache.save(path)

    fresh = dispatch.AutotuneCache(timer=seq_timer([99.0] * 10))
    assert fresh.load(path) == 2
    assert fresh.choose(8, 64, 32, 1, 1) == first
    assert fresh.choose(8, 64, 64, 8, 1, tag="decode") == "popcount"
    assert fresh.timing_runs == 0  # persisted verdicts, no warmup

    blob = json.load(open(path))
    assert blob["version"] == 1
    assert {e["backend"] for e in blob["entries"]} <= set(dispatch.BACKENDS)


def test_failed_tuning_falls_back_but_is_never_persisted(tmp_path):
    """A timing pass where every probe raises yields an in-process mxu
    fallback; save() must not write it, so the next process re-times."""

    def exploding_timer(fn):
        raise RuntimeError("transient OOM")

    path = str(tmp_path / "autotune.json")
    cache = dispatch.AutotuneCache(timer=exploding_timer)
    assert cache.choose(8, 64, 32, 1, 1) == dispatch.DEFAULT_BACKEND
    (rec,) = cache.entries.values()
    assert rec.failed and not rec.timed
    cache.save(path)
    assert json.load(open(path))["entries"] == []
    fresh = dispatch.AutotuneCache(timer=seq_timer([3.0, 1.0, 2.0, 4.0]))
    fresh.load(path)
    assert fresh.choose(8, 64, 32, 1, 1) == "popcount"  # re-timed, not pinned


def test_load_skips_unknown_backends(tmp_path):
    path = str(tmp_path / "autotune.json")
    cache = dispatch.AutotuneCache(timer=seq_timer([1.0] * 10))
    cache.choose(8, 64, 32, 1, 1)
    blob = cache.to_json()
    blob["entries"][0]["backend"] = "fpga"  # a backend this build lacks
    with open(path, "w") as f:
        json.dump(blob, f)
    assert dispatch.AutotuneCache().load(path) == 0


# ---------------------------------------------------------------------------
# forced per-layer overrides
# ---------------------------------------------------------------------------


def test_backend_for_resolves_overrides():
    q = QuantConfig(
        backend="mxu",
        backend_overrides=(("ffn.down", "popcount"), ("attn.*", "pallas")),
    )
    assert q.backend_for("ffn.down") == "popcount"
    assert q.backend_for("ffn.up") == "mxu"
    assert q.backend_for("attn.q") == "pallas"
    assert q.backend_for("") == "mxu"


def test_quant_config_rejects_unknown_backends():
    with pytest.raises(ValueError, match="unknown backend 'dsp'"):
        QuantConfig(backend="dsp")
    with pytest.raises(ValueError, match="popcnt"):
        QuantConfig(backend_overrides=(("ffn.down", "popcnt"),))


def test_qlinear_threads_forced_backend(monkeypatch):
    from repro.models import layers as L

    seen = []
    real_qmm = QE.qmm

    def spy(x, w, **kw):
        seen.append(kw.get("backend"))
        return real_qmm(x, w, **kw)

    monkeypatch.setattr(L.QE, "qmm", spy)
    quant = QuantConfig(
        act_bits=4, backend="mxu", backend_overrides=(("proj", "popcount"),)
    )
    p = L.init_linear(jax.random.PRNGKey(0), 64, 32)
    sp = L.pack_linear_for_serving(p, quant)
    x = jnp.asarray(RNG.standard_normal((4, 64)).astype(np.float32))
    forced = L.qlinear(sp, x, quant, "serve", name="proj")
    default = L.qlinear(sp, x, quant, "serve")
    assert seen == ["popcount", "mxu"]
    np.testing.assert_allclose(
        np.asarray(forced), np.asarray(default), rtol=1e-4, atol=1e-3
    )


# ---------------------------------------------------------------------------
# numerical parity: every dispatched backend vs the kernels/ref.py oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", dispatch.BACKENDS)
@pytest.mark.parametrize("act_bits", [1, 4, 8])
def test_backend_parity_act_weight(backend, act_bits):
    xq, wq = _quant_pair(16, 96, 24, act_bits)
    expect = FA.qmm_dequant_reference(xq, wq)
    out = QE.qmm(xq, wq, backend=backend)
    tol = 3e-5 * max(1.0, float(jnp.max(jnp.abs(expect))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=tol)


@pytest.mark.parametrize("backend", dispatch.BACKENDS)
def test_backend_parity_act_act(backend):
    a = jnp.asarray(RNG.standard_normal((12, 40)).astype(np.float32))
    b = jnp.asarray(RNG.standard_normal((40, 20)).astype(np.float32))
    aq = Q.quantize_activation(a, 4)
    bq = Q.quantize_activation(b, 4)
    expect = FA.qmm_dequant_reference(aq, bq)
    out = QE.qmm(aq, bq, backend=backend)
    tol = 3e-4 * max(1.0, float(jnp.max(jnp.abs(expect))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=tol)


def test_popcount_core_matches_bitserial_oracle():
    """The popcount backend's integer core == ref.bitserial_qmm_ref == A @ B."""
    m, k, n, bits = 16, 128, 24, 4
    a = RNG.integers(0, 2**bits, size=(m, k)).astype(np.int32)
    b = RNG.integers(0, 2**bits, size=(k, n)).astype(np.int32)
    core = QE.popcount_int_matmul(jnp.asarray(a), jnp.asarray(b), bits, bits)
    apl = packing.pack_bitplanes(jnp.asarray(a), bits, axis=-1)
    bpl = packing.pack_bitplanes(jnp.asarray(b), bits, axis=-2)
    oracle = ref.bitserial_qmm_ref(apl, bpl, k)
    np.testing.assert_array_equal(np.asarray(core), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(core), a @ b)
