from repro.optim import adamw, compression

__all__ = ["adamw", "compression"]
