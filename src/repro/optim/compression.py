"""int8 error-feedback gradient compression for cross-pod data parallelism.

Beyond-paper but in the paper's spirit: BETA's thesis is that low-bit
integer traffic is nearly free relative to full-precision — the same holds
for the *gradient* all-reduce that dominates cross-pod (DCN/ICI-limited)
communication at 1000+-node scale.  Each DP step:

    1. residual-corrected gradient:  g' = g + e        (error feedback)
    2. quantize per-leaf to int8:    q = round(g' / s),  s = max|g'| / 127
    3. all-reduce the int8 payload (4x fewer bytes than fp32; the mean of
       per-shard scales rides along as a tiny fp32 side channel)
    4. new residual:                 e = g' - dequant(q)

Error feedback keeps the scheme unbiased-in-the-limit (residuals re-enter
the next step), which is what makes 8-bit all-reduce safe for QAT training.
Used by runtime/train_loop.py when ``compress_pod_grads`` is on; the unit
tests check the contraction property ``|e_t|`` bounded and end-to-end loss
parity within tolerance.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress", "decompress", "compressed_psum"]


def init_error_state(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 payload, fp32 scale, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    residual = corrected - q.astype(jnp.float32) * scale
    return q, scale, residual


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads, err_state, axis_name: str, enabled: bool = True
) -> Tuple[Any, Any]:
    """All-reduce a gradient pytree across ``axis_name`` with int8 payloads.

    Inside shard_map/pmapped code: each shard compresses (with its running
    error residual), the int8 tensors are psum'd (wire bytes /4), and the
    result is rescaled by the psum of scales / n.  Returns
    (averaged grads, new error state).

    With ``enabled=False`` falls back to plain fp32 psum-mean (the control
    arm for the §Perf ablation).
    """
    n = jax.lax.psum(jnp.ones(()), axis_name)
    if not enabled:
        avg = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n, grads
        )
        return avg, err_state

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        # shared scale: one scalar pmax per leaf precedes the payload (the
        # standard low-bit all-reduce handshake) — per-shard scales would
        # make the int8 sum biased.
        local_max = jnp.max(jnp.abs(corrected))
        global_max = jax.lax.pmax(local_max, axis_name)
        scale = jnp.maximum(global_max, 1e-12) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        resid = corrected - q.astype(jnp.float32) * scale
        # int8 psum: sum of payloads fits int32 accumulators
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        avg = q_sum.astype(jnp.float32) * scale / n
        return avg.astype(g.dtype), resid

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
