"""AdamW + schedules + clipping, pure JAX (no optax in this container).

QAT specifics: the latent fp32 weights receive STE gradients from the
fake-quantized forward; weight decay is applied to latent weights only
(norm gains / biases / recurrence params are excluded by the standard
dimension heuristic).  State is a pytree congruent with params — it shards
identically (runtime/sharding.py maps both with the same rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_state", "apply_updates", "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Any  # first moment (pytree like params)
    nu: Any  # second moment
    step: jax.Array


def init_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(mu=zeros, nu=jax.tree.map(jnp.zeros_like, zeros), step=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(params):
    # decay only matrices (ndim >= 2): embeddings, projections, experts
    return jax.tree.map(lambda p: float(p.ndim >= 2), params)


def apply_updates(
    params,
    grads,
    state: OptState,
    cfg: AdamWConfig,
) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)

    def upd(p, g, m, v, dm):
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * g
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * dm * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_dm = treedef.flatten_up_to(mask)
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_dm)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        OptState(mu=new_m, nu=new_v, step=step),
        {"grad_norm": gnorm, "lr": lr},
    )
