"""Pallas TPU kernel: AND+popcount binary QMM — the faithful DPU analogue.

This is BETA's dot-product unit (Fig. 3b) transcribed to the TPU *vector*
unit: both operands stay bit-packed in uint32 lanes; a PE-sequence step is
``and`` + ``population_count`` on whole VREGs (32 binary MACs per lane-op),
and the compressor-tree is a log-depth integer tree-sum over the word axis,
with the int32 accumulator tile carried across the K-grid in VMEM (the
compressor-tree *loop*).

With the unified unsigned-mantissa form ({0,1} rather than +-1), XNOR-
popcount becomes AND-popcount; the affine flow-abstraction epilogue absorbs
the difference — one datapath for both operand kinds, like BETA.

When to use which kernel (DESIGN.md §Perf napkin math): each VPU lane-op
does 32 1-bit MACs; the MXU int8 path does 1 MAC/lane but on the 128x128
systolic array at ~2x bf16 clocking.  On v5e the MXU path wins for K
greater than ~256 at bm,bn >= 128; the popcount path wins for skinny/small
QMMs (edge regime, exactly the paper's target) and when int8 unpack traffic
dominates.  Both are exposed; benchmarks/qmm_micro quantifies the crossover.

Blocking: grid = (M/bm, N/bn, Kw/bkw), K innermost.
  A  (bm, bkw)  uint32  — packed left mantissas (K packed along -1)
  B  (bkw, bn)  uint32  — packed right mantissas (K packed along -2)
  O  (bm, bn)   int32

VMEM @ defaults (64, 128, 64): A 16 KiB + B 32 KiB + joint (64,128,64) int32
2 MiB... the joint broadcast is avoided by looping words in VREG-sized
chunks; the body below trades a small fori_loop over the word axis for a
bounded footprint (acc + 2 operand tiles ~ 100 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["popcount_qmm", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (64, 128, 64)  # bm, bn, bkw (bkw in 32-bit WORDS of K)


def _kernel(a_ref, b_ref, o_ref, *, bkw: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (bm, bkw) uint32
    b = b_ref[...]  # (bkw, bn) uint32

    def word_step(w, acc):
        # One unfolded PE-sequence step: 32 binary MACs per (m, n) lane pair.
        aw = jax.lax.dynamic_slice_in_dim(a, w, 1, axis=1)  # (bm, 1)
        bw = jax.lax.dynamic_slice_in_dim(b, w, 1, axis=0)  # (1, bn)
        joint = jnp.bitwise_and(aw, bw)  # broadcast -> (bm, bn)
        return acc + jax.lax.population_count(joint).astype(jnp.int32)

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    acc = jax.lax.fori_loop(0, bkw, word_step, acc)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def popcount_qmm(
    a_packed: jax.Array,
    b_packed: jax.Array,
    *,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Binary integer MM over packed operands: ``unpack(a) @ unpack(b)``.

    Args:
      a_packed: uint32 ``(M, Kw)``; K bit-packed along the last axis.
      b_packed: uint32 ``(Kw, N)``; K bit-packed along the first axis.
      block: (bm, bn, bkw) tile sizes; Kw (words) must divide by bkw.
      interpret: CPU validation mode.

    Returns:
      int32 ``(M, N)`` — popcount-accumulated binary dot products.
    """
    m, kw = a_packed.shape
    kw2, n = b_packed.shape
    if kw != kw2:
        raise ValueError(f"packed-K mismatch: {a_packed.shape} vs {b_packed.shape}")
    bm, bn, bkw = block
    if m % bm or n % bn or kw % bkw:
        raise ValueError(f"shapes ({m},{kw},{n}) not multiples of block {block}")

    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        functools.partial(_kernel, bkw=bkw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkw, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_packed, b_packed)
