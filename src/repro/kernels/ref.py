"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each oracle states the *integer* semantics of its kernel: unpack whatever is
packed, do the matmul in plain jnp, return int32.  Kernels must match these
bit-exactly (integer math); tests sweep shapes and dtypes against them.

Every oracle asserts its input contract at entry (packing dtype, rank, and
reduction-length consistency).  A parity test handing an oracle a float or
mis-packed operand would otherwise silently promote through ``jnp.dot`` and
"pass" against a kernel making the same mistake — the asserts make the
contract violation loud at the oracle boundary instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing

__all__ = [
    "binary_qmm_ref",
    "popcount_qmm_ref",
    "bitserial_qmm_ref",
    "fused_qmm_ref",
    "binary_attn_scores_ref",
]


def _packed_words(k: int) -> int:
    return (k + 31) // 32


def _check_packed(name: str, x: jax.Array, k: int, axis: int) -> None:
    """Packed operands are uint32 with ceil(k/32) words along ``axis``."""
    if x.dtype != jnp.uint32:
        raise TypeError(
            f"{name}: packed operand must be uint32 bit-planes, got {x.dtype}"
        )
    if x.shape[axis] != _packed_words(k):
        raise ValueError(
            f"{name}: packed axis {axis} has {x.shape[axis]} words, "
            f"expected ceil({k}/32) = {_packed_words(k)}"
        )


def binary_qmm_ref(a: jax.Array, w_packed: jax.Array, k: int) -> jax.Array:
    """Oracle for ``binary_qmm``: ``a (M, K) int8  @  unpack(w_packed) (K, N)``.

    ``w_packed`` is uint32 ``(ceil(K/32), N)``, 1-bit mantissas packed along
    the reduction dim; mantissa values are {0, 1}.
    """
    if not jnp.issubdtype(a.dtype, jnp.integer):
        raise TypeError(
            f"binary_qmm_ref: activation mantissa must be integer, got {a.dtype}"
        )
    if a.shape[-1] != k:
        raise ValueError(
            f"binary_qmm_ref: a has K={a.shape[-1]}, caller declared k={k}"
        )
    if w_packed.ndim != 2:
        raise ValueError(f"binary_qmm_ref: w_packed must be rank 2, got {w_packed.ndim}")
    _check_packed("binary_qmm_ref", w_packed, k, axis=0)
    w = packing.unpack_bits(w_packed, 1, k, axis=0, dtype=jnp.int32)
    return jnp.dot(a.astype(jnp.int32), w, preferred_element_type=jnp.int32)


def popcount_qmm_ref(a_packed: jax.Array, b_packed: jax.Array, k: int) -> jax.Array:
    """Oracle for ``popcount_qmm``: binary x binary over packed operands.

    ``out[m, n] = sum_j a[m, j] * b[j, n]`` with a, b in {0,1};
    a_packed ``(M, Kw)`` packed along axis -1, b_packed ``(Kw, N)`` along 0.
    """
    if a_packed.ndim != 2 or b_packed.ndim != 2:
        raise ValueError(
            "popcount_qmm_ref: operands must be rank 2, got "
            f"{a_packed.ndim} and {b_packed.ndim}"
        )
    _check_packed("popcount_qmm_ref", a_packed, k, axis=-1)
    _check_packed("popcount_qmm_ref", b_packed, k, axis=0)
    a = packing.unpack_bits(a_packed, 1, k, axis=-1, dtype=jnp.int32)
    b = packing.unpack_bits(b_packed, 1, k, axis=0, dtype=jnp.int32)
    return jnp.dot(a, b, preferred_element_type=jnp.int32)


def bitserial_qmm_ref(
    a_planes: jax.Array, b_planes: jax.Array, k: int
) -> jax.Array:
    """Oracle for ``bitserial_qmm`` (multi-bit act x act, paper Fig. 4).

    ``a_planes``: uint32 ``(a_bits, M, Kw)`` — bit-planes of the left
    mantissa, each 1-bit packed along the last axis.
    ``b_planes``: uint32 ``(b_bits, Kw, N)`` — bit-planes of the right
    mantissa, packed along axis -2.

    Result: ``sum_ij 2^(i+j) * (A_i @ B_j)`` == ``A @ B`` for the original
    multi-bit mantissas.
    """
    if a_planes.ndim != 3 or b_planes.ndim != 3:
        raise ValueError(
            "bitserial_qmm_ref: plane stacks must be rank 3 (bits, ., .), got "
            f"{a_planes.ndim} and {b_planes.ndim}"
        )
    _check_packed("bitserial_qmm_ref", a_planes, k, axis=-1)
    _check_packed("bitserial_qmm_ref", b_planes, k, axis=-2)
    a_bits = a_planes.shape[0]
    b_bits = b_planes.shape[0]
    out = None
    for i in range(a_bits):
        ai = packing.unpack_bits(a_planes[i], 1, k, axis=-1, dtype=jnp.int32)
        for j in range(b_bits):
            bj = packing.unpack_bits(b_planes[j], 1, k, axis=-2, dtype=jnp.int32)
            part = jnp.dot(ai, bj, preferred_element_type=jnp.int32) << (i + j)
            out = part if out is None else out + part
    return out


def fused_qmm_ref(
    a_planes: jax.Array,
    b_planes: jax.Array,
    a_scale: jax.Array,
    a_offset: jax.Array,
    w_scale: jax.Array,
    w_offset: jax.Array,
    k: int,
) -> jax.Array:
    """Oracle for ``fused_qmm``: bit-serial integer core + affine epilogue.

    The integer part is exactly :func:`bitserial_qmm_ref`; the epilogue is
    the flow abstraction on *unsigned* mantissas, evaluated in the same
    elementwise fp32 expression order as the kernel.  The fused kernel
    matches this oracle bit-exactly whenever the epilogue arithmetic is
    exact (dyadic scales/offsets — see ``kernels.fused_qmm``); otherwise to
    last-ulp fma-contraction differences.
    """
    xy = bitserial_qmm_ref(a_planes, b_planes, k)
    a_bits = a_planes.shape[0]
    b_bits = b_planes.shape[0]
    row = None
    col = None
    for i in range(a_bits):
        ai = packing.unpack_bits(a_planes[i], 1, k, axis=-1, dtype=jnp.int32)
        part = jnp.sum(ai, axis=-1, keepdims=True, dtype=jnp.int32) << i
        row = part if row is None else row + part
    for j in range(b_bits):
        bj = packing.unpack_bits(b_planes[j], 1, k, axis=-2, dtype=jnp.int32)
        part = jnp.sum(bj, axis=-2, keepdims=True, dtype=jnp.int32) << j
        col = part if col is None else col + part
    a1 = a_scale.astype(jnp.float32)
    g1 = a_offset.astype(jnp.float32)
    a2 = w_scale.astype(jnp.float32)
    g2 = w_offset.astype(jnp.float32)
    t0 = xy.astype(jnp.float32) * (a1 * a2)
    t1 = (a1 * g2) * row.astype(jnp.float32)
    t2 = (g1 * a2) * col.astype(jnp.float32)
    t3 = g1 * g2 * jnp.float32(k)
    return ((t0 + t1) + t2) + t3


def _unpack_bits_np(planes: np.ndarray, length: int) -> np.ndarray:
    """NumPy unpack of 1-bit little-endian planes along the last axis."""
    shifts = np.arange(32, dtype=np.uint32)
    bits = (planes[..., :, None] >> shifts) & np.uint32(1)
    bits = bits.reshape(*planes.shape[:-1], planes.shape[-1] * 32)
    return bits[..., :length].astype(np.int32)


def binary_attn_scores_ref(
    q_planes: np.ndarray, k_planes: np.ndarray, dh: int
) -> np.ndarray:
    """Pure-NumPy oracle for the scores family: the bit-exactness contract.

    ``out[b, h, s, t] = sum_d q_bits[b, h, s, d] * k_bits[b, h // g, t, d]``
    over {0, 1} bits, int32 — head ``h`` reads kv head ``h // (H/G)`` (GQA
    head expansion).  Every registered scores backend's ``run_scores`` must
    match this exactly; the affine epilogue back to the real-valued score
    domain is shared caller code and is NOT part of this contract.

    Operands are uint32 ``(B, H, S, dw)`` / ``(B, G, T, dw)`` with ``dh``
    bits packed little-endian along the last axis.
    """
    q_planes = np.asarray(q_planes)
    k_planes = np.asarray(k_planes)
    for name, x in (("q_planes", q_planes), ("k_planes", k_planes)):
        if x.dtype != np.uint32:
            raise TypeError(
                f"binary_attn_scores_ref: {name} must be uint32, got {x.dtype}"
            )
        if x.ndim != 4:
            raise ValueError(
                f"binary_attn_scores_ref: {name} must be rank 4, got {x.ndim}"
            )
        if x.shape[-1] != _packed_words(dh):
            raise ValueError(
                f"binary_attn_scores_ref: {name} packed axis has "
                f"{x.shape[-1]} words, expected ceil({dh}/32) = {_packed_words(dh)}"
            )
    b, h, s, _ = q_planes.shape
    g, t = k_planes.shape[1], k_planes.shape[2]
    if h % g:
        raise ValueError(f"binary_attn_scores_ref: H={h} not a multiple of G={g}")
    qb = _unpack_bits_np(q_planes, dh)
    kb = np.repeat(_unpack_bits_np(k_planes, dh), h // g, axis=1)
    out = np.einsum("bhsd,bhtd->bhst", qb.astype(np.int64), kb.astype(np.int64))
    return out.astype(np.int32)
