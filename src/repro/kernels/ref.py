"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each oracle states the *integer* semantics of its kernel: unpack whatever is
packed, do the matmul in plain jnp, return int32.  Kernels must match these
bit-exactly (integer math); tests sweep shapes and dtypes against them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing

__all__ = ["binary_qmm_ref", "popcount_qmm_ref", "bitserial_qmm_ref"]


def binary_qmm_ref(a: jax.Array, w_packed: jax.Array, k: int) -> jax.Array:
    """Oracle for ``binary_qmm``: ``a (M, K) int8  @  unpack(w_packed) (K, N)``.

    ``w_packed`` is uint32 ``(ceil(K/32), N)``, 1-bit mantissas packed along
    the reduction dim; mantissa values are {0, 1}.
    """
    w = packing.unpack_bits(w_packed, 1, k, axis=0, dtype=jnp.int32)
    return jnp.dot(a.astype(jnp.int32), w, preferred_element_type=jnp.int32)


def popcount_qmm_ref(a_packed: jax.Array, b_packed: jax.Array, k: int) -> jax.Array:
    """Oracle for ``popcount_qmm``: binary x binary over packed operands.

    ``out[m, n] = sum_j a[m, j] * b[j, n]`` with a, b in {0,1};
    a_packed ``(M, Kw)`` packed along axis -1, b_packed ``(Kw, N)`` along 0.
    """
    a = packing.unpack_bits(a_packed, 1, k, axis=-1, dtype=jnp.int32)
    b = packing.unpack_bits(b_packed, 1, k, axis=0, dtype=jnp.int32)
    return jnp.dot(a, b, preferred_element_type=jnp.int32)


def bitserial_qmm_ref(
    a_planes: jax.Array, b_planes: jax.Array, k: int
) -> jax.Array:
    """Oracle for ``bitserial_qmm`` (multi-bit act x act, paper Fig. 4).

    ``a_planes``: uint32 ``(a_bits, M, Kw)`` — bit-planes of the left
    mantissa, each 1-bit packed along the last axis.
    ``b_planes``: uint32 ``(b_bits, Kw, N)`` — bit-planes of the right
    mantissa, packed along axis -2.

    Result: ``sum_ij 2^(i+j) * (A_i @ B_j)`` == ``A @ B`` for the original
    multi-bit mantissas.
    """
    a_bits = a_planes.shape[0]
    b_bits = b_planes.shape[0]
    out = None
    for i in range(a_bits):
        ai = packing.unpack_bits(a_planes[i], 1, k, axis=-1, dtype=jnp.int32)
        for j in range(b_bits):
            bj = packing.unpack_bits(b_planes[j], 1, k, axis=-2, dtype=jnp.int32)
            part = jnp.dot(ai, bj, preferred_element_type=jnp.int32) << (i + j)
            out = part if out is None else out + part
    return out
