"""jit'd wrappers around the Pallas kernels: padding, dispatch, epilogues.

Public entry points take logical (unpadded) shapes, pad to kernel block
multiples, invoke the kernel, slice back, and (for the QuantTensor entry)
apply the flow-abstraction epilogue.  ``interpret`` defaults to
auto-detection: real kernels on TPU, interpret mode elsewhere — the same
switch the model layer uses.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend_registry, flow_abstraction, packing, quantization
from repro.core.quantization import QuantTensor
from repro.kernels import binary_attn as _ba
from repro.kernels import binary_qmm as _bq
from repro.kernels import bitserial_qmm as _bs
from repro.kernels import fused_qmm as _fq
from repro.kernels import popcount_qmm as _pq

__all__ = [
    "on_tpu",
    "binary_qmm_int",
    "popcount_qmm_int",
    "bitserial_qmm_int",
    "qmm_pallas",
    "qmm_fused",
    "binary_attn_scores",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret: Optional[bool]) -> bool:
    return (not on_tpu()) if interpret is None else interpret


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def binary_qmm_int(
    a: jax.Array,
    w_packed: jax.Array,
    k: int,
    *,
    block=_bq.DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``a (M, K) int8 @ unpack(w_packed) (K, N)`` with auto-padding.

    Zero padding is exact: padded activation columns hit padded (zero) weight
    rows; padded rows/cols are sliced off.
    """
    bm, bn, bk = block
    m, _ = a.shape
    n = w_packed.shape[1]
    a_p = _pad_to(_pad_to(a, 0, bm), 1, bk)
    kp = a_p.shape[1]
    # pad packed weights along words to kp/32, then columns to bn
    w_p = _pad_to(_pad_to(w_packed, 0, kp // 32), 1, bn)
    out = _bq.binary_qmm(
        a_p, w_p, k=kp, block=block, interpret=_auto_interpret(interpret)
    )
    return out[:m, :n]


def popcount_qmm_int(
    a_packed: jax.Array,
    b_packed: jax.Array,
    *,
    block=_pq.DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Binary x binary over packed operands with auto-padding (M, N, Kw)."""
    bm, bn, bkw = block
    m, kw = a_packed.shape
    n = b_packed.shape[1]
    a_p = _pad_to(_pad_to(a_packed, 0, bm), 1, bkw)
    b_p = _pad_to(_pad_to(b_packed, 0, a_p.shape[1]), 1, bn)
    out = _pq.popcount_qmm(a_p, b_p, block=block, interpret=_auto_interpret(interpret))
    return out[:m, :n]


def bitserial_qmm_int(
    a_planes: jax.Array,
    b_planes: jax.Array,
    *,
    block=_bs.DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Multi-bit act x act from packed planes with auto-padding."""
    bm, bn, bkw = block
    _, m, kw = a_planes.shape
    n = b_planes.shape[2]
    a_p = _pad_to(_pad_to(a_planes, 1, bm), 2, bkw)
    b_p = _pad_to(_pad_to(b_planes, 1, a_p.shape[2]), 2, bn)
    out = _bs.bitserial_qmm(a_p, b_p, block=block, interpret=_auto_interpret(interpret))
    return out[:m, :n]


def qmm_pallas(
    x: QuantTensor,
    w: QuantTensor,
    *,
    w_colsum: Optional[jax.Array] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """QuantTensor QMM routed through the Pallas kernels + flow epilogue.

    Dispatch (mirrors BETA's mode table, Fig. 4):
      * weight_bits == 1 and act mantissa int8-representable -> binary_qmm
        (fused unpack + MXU int8) — act x weight, any act precision.
      * 1-bit x 1-bit -> popcount_qmm on fully packed operands.
      * multi-bit act x act -> bitserial_qmm over bit-planes.

    Only rank-2 operands hit the kernels; callers flatten leading batch dims
    (the model layer does).  Falls back to the jnp paths for other cases.
    """
    x_l = x.logical_shape
    w_l = w.logical_shape
    if len(w_l) != 2 or len(x_l) != 2:
        raise ValueError("qmm_pallas expects rank-2 operands; flatten batch dims")
    k = x_l[-1]

    if x.bits == 1 and w.bits == 1:
        a_packed = (
            x.mantissa if x.packed else packing.pack_bits(x.mantissa, 1, axis=-1)
        )
        b_packed = (
            w.mantissa if w.packed else packing.pack_bits(w.mantissa, 1, axis=0)
        )
        xy = popcount_qmm_int(a_packed, b_packed, interpret=interpret)
        return _epilogue(x, w, xy, k, w_colsum, out_dtype)

    if w.bits == 1:
        # act x weight: re-center activations (exact), unpack to int8.
        xr = quantization.recenter(x)
        a8 = xr.unpack(dtype=jnp.int8).mantissa
        b_packed = (
            w.mantissa if w.packed else packing.pack_bits(w.mantissa, 1, axis=0)
        )
        xy = binary_qmm_int(a8, b_packed, k, interpret=interpret)
        return _epilogue(xr, w, xy, k, w_colsum, out_dtype)

    # multi-bit act x act: bit-serial planes (unsigned mantissas).
    a_planes = packing.pack_bitplanes(
        x.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), x.bits, axis=-1
    )
    b_planes = packing.pack_bitplanes(
        w.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), w.bits, axis=-2
    )
    xy = bitserial_qmm_int(a_planes, b_planes, interpret=interpret)
    return _epilogue(x, w, xy, k, w_colsum, out_dtype)


def qmm_fused(
    x: QuantTensor,
    w: QuantTensor,
    *,
    w_colsum: Optional[jax.Array] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
    block=_fq.DEFAULT_BLOCK,
) -> jax.Array:
    """QuantTensor QMM through the *fused* bit-serial kernel.

    One Pallas pass does everything: packed planes in, AND-popcount
    cross-plane accumulation, and the affine epilogue on-chip — the integer
    MM never round-trips HBM (contrast ``qmm_pallas``, which stages the
    integer result and applies the epilogue as a separate XLA computation).

    ``w_colsum`` is accepted for signature parity with the other backends but
    ignored: the kernel accumulates ``colsum(W)`` from the same packed planes
    it is already popcounting, so a precomputed colsum saves nothing.
    """
    x_l = x.logical_shape
    w_l = w.logical_shape
    if len(w_l) != 2 or len(x_l) != 2:
        raise ValueError("qmm_fused expects rank-2 operands; flatten batch dims")
    del w_colsum  # computed in-kernel from the planes already on chip
    m, k = x_l
    n = w_l[-1]

    # Raw unsigned mantissa planes (the popcount contract — no re-centering).
    if x.packed and x.bits == 1:
        a_planes = x.mantissa.astype(jnp.uint32)[None]  # (1, M, Kw)
    else:
        a_planes = packing.pack_bitplanes(
            x.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), x.bits, axis=-1
        )
    if w.packed and w.bits == 1:
        b_planes = w.mantissa.astype(jnp.uint32)[None]  # (1, Kw, N)
    else:
        b_planes = packing.pack_bitplanes(
            w.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), w.bits, axis=-2
        )

    f32 = jnp.float32
    a_scale = jnp.broadcast_to(jnp.asarray(x.scale, f32), (m, 1))
    a_off = jnp.broadcast_to(jnp.asarray(x.offset, f32), (m, 1))
    w_scale = jnp.broadcast_to(jnp.asarray(w.scale, f32), (1, n))
    w_off = jnp.broadcast_to(jnp.asarray(w.offset, f32), (1, n))

    bm, bn, bkw = block
    a_p = _pad_to(_pad_to(a_planes, 1, bm), 2, bkw)
    b_p = _pad_to(_pad_to(b_planes, 1, a_p.shape[2]), 2, bn)
    out = _fq.fused_qmm(
        a_p,
        b_p,
        _pad_to(a_scale, 0, bm),
        _pad_to(a_off, 0, bm),
        _pad_to(w_scale, 1, bn),
        _pad_to(w_off, 1, bn),
        k=k,
        block=block,
        interpret=_auto_interpret(interpret),
    )[:m, :n]
    return out if out_dtype == jnp.float32 else out.astype(out_dtype)


def _epilogue(x, w, xy, k, w_colsum, out_dtype):
    """Flow-abstraction corrections on the kernel's integer MM output.

    Valid for any mantissa representation (signed/unsigned) because the
    affine identity holds verbatim — re-centering only moves the offsets.
    ``w_colsum``, when provided, must be the colsum of the mantissas exactly
    as the kernel consumed them (weight_corrections() handles this).
    """
    x1 = x.unpack(dtype=jnp.int32).mantissa
    a1 = jnp.asarray(x.scale, out_dtype)
    g1 = jnp.asarray(x.offset, out_dtype)
    a2 = jnp.asarray(w.scale, out_dtype)
    g2 = jnp.asarray(w.offset, out_dtype)
    out = xy.astype(out_dtype) * (a1 * a2)
    row = jnp.sum(x1, axis=-1, dtype=jnp.int32)[..., None].astype(out_dtype)
    out = out + (a1 * g2) * row
    col = (
        w_colsum
        if w_colsum is not None
        else jnp.sum(w.unpack(dtype=jnp.int32).mantissa, axis=-2, dtype=jnp.int32)
    )
    out = out + (g1 * a2) * col[..., None, :].astype(out_dtype)
    return out + g1 * g2 * jnp.asarray(k, out_dtype)


# ---------------------------------------------------------------------------
# Backend registration — the Pallas-backed entries of the QMM registry.
# (core.qmm registers the jnp backends "mxu" and "popcount".)
# ---------------------------------------------------------------------------

# Off-TPU the kernels run in interpret mode — a correctness fallback, not a
# performance contender; only offer them on problems small enough that one
# autotune timing probe stays cheap.
_INTERPRET_MAX_MKN = 1 << 24


def _interpret_probe(m: int, k: int, n: int) -> bool:
    return on_tpu() or m * k * n <= _INTERPRET_MAX_MKN


def _packed_operand_bytes(m, k, n, act_bits, weight_bits):
    """HBM footprint of fully bit-plane-packed operands, in bytes."""
    kw_bytes = 4 * packing.packed_len(k, 1)
    return act_bits * m * kw_bytes, weight_bits * kw_bytes * n


def _traffic_pallas(m, k, n, act_bits, weight_bits) -> int:
    # Staged kernels: the int32 MM result round-trips HBM (write + read)
    # before the XLA epilogue writes the fp32 output — 12 bytes/element of
    # output traffic vs the fused kernel's 4.
    if weight_bits == 1 and act_bits > 1:
        a_bytes = m * k  # binary_qmm path: re-centered int8 activations
        b_bytes = 4 * packing.packed_len(k, 1) * n
    else:
        a_bytes, b_bytes = _packed_operand_bytes(m, k, n, act_bits, weight_bits)
    return a_bytes + b_bytes + 12 * m * n + 8 * (m + n)


def _traffic_fused(m, k, n, act_bits, weight_bits) -> int:
    # Packed planes fetched once, fp32 out written once — nothing staged.
    a_bytes, b_bytes = _packed_operand_bytes(m, k, n, act_bits, weight_bits)
    return a_bytes + b_bytes + 4 * m * n + 8 * (m + n)


backend_registry.register(
    backend_registry.QMMBackend(
        name="pallas",
        run=qmm_pallas,
        description="staged Pallas kernels (binary/popcount/bitserial) "
        "+ XLA flow epilogue",
        rank2_only=True,
        probe=_interpret_probe,
        traffic_model=_traffic_pallas,
    )
)

backend_registry.register(
    backend_registry.QMMBackend(
        name="fused",
        run=qmm_fused,
        description="one fused Pallas kernel: bit-serial AND-popcount core "
        "+ on-chip affine epilogue",
        rank2_only=True,
        needs_unsigned_mantissas=True,
        probe=_interpret_probe,
        traffic_model=_traffic_fused,
    )
)


# ---------------------------------------------------------------------------
# Scores family: rank-4 attention-scores cores (W1A1 packed planes).
# "mxu" also serves this family (registered in core.qmm); these two are
# scores-only, so the qmm entry point rejects them by family.
# ---------------------------------------------------------------------------


def binary_attn_scores(
    q_planes: jax.Array,
    k_planes: jax.Array,
    *,
    dh: int,
    backend: str = "auto",
    tag: Optional[str] = None,
) -> jax.Array:
    """Attention-scores integer core, backend-dispatched (scores family).

    ``backend="auto"`` consults the autotune cache under the "scores" family
    key (m = B*H*S, k = dh, n = T); explicit names resolve through the
    demotion table exactly like ``qmm`` — every scores core is bit-exact
    against ``ref.binary_attn_scores_ref``, so neither autotuning nor a
    demotion can change numerics.
    """
    from repro.core import dispatch

    b, h, s, _ = q_planes.shape
    t = k_planes.shape[2]
    if backend == "auto":
        backend = dispatch.choose_scores_backend(b, h, s, t, dh, tag=tag)
    else:
        backend = dispatch.resolve_backend(backend)
    spec = backend_registry.get_backend(backend)
    if "scores" not in spec.families or spec.run_scores is None:
        raise ValueError(
            f"backend {backend!r} does not serve the scores family; "
            f"scores backends: "
            f"{', '.join(backend_registry.backend_names(family='scores'))}"
        )
    return spec.run_scores(q_planes, k_planes, dh=dh)


def _float_scores(q_planes: jax.Array, k_planes: jax.Array, *, dh: int) -> jax.Array:
    """Float-dot scores core: unpack the {0,1} planes to f32 and einsum.

    The differential oracle's compute path — exact (hence bit-exact vs the
    popcount cores) because counts are bounded by dh << 2^24, within f32's
    integer-exact range.
    """
    qb = packing.unpack_bits(q_planes, 1, dh, axis=-1, dtype=jnp.float32)
    kb = packing.unpack_bits(k_planes, 1, dh, axis=-1, dtype=jnp.float32)
    b, h, s, _ = qb.shape
    g = kb.shape[1]
    qg = qb.reshape(b, g, h // g, s, dh)
    out = jnp.einsum("bgxsd,bgtd->bgxst", qg, kb)
    return out.reshape(b, h, s, kb.shape[2]).astype(jnp.int32)


def _traffic_scores_binary(m, k, n, act_bits, weight_bits) -> int:
    # Packed planes in, int32 counts out: m and n rows of ceil(k/32) words.
    kw_bytes = 4 * packing.packed_len(k, 1)
    return m * kw_bytes + n * kw_bytes + 4 * m * n


backend_registry.register(
    backend_registry.QMMBackend(
        name="binary",
        run=_ba.binary_attn_scores_planes,  # scores-only: qmm rejects by family
        run_scores=_ba.binary_attn_scores_planes,
        description="rank-4 AND-popcount attention scores over packed "
        "uint32 Q/K bit-planes (Bitformer path)",
        precisions=frozenset({(1, 1)}),
        needs_unsigned_mantissas=True,
        families=frozenset({"scores"}),
        traffic_model=_traffic_scores_binary,
    )
)

backend_registry.register(
    backend_registry.QMMBackend(
        name="float",
        run=_float_scores,  # scores-only: qmm rejects by family
        run_scores=_float_scores,
        description="float-dot attention scores over unpacked {0,1} planes "
        "(the differential oracle's compute path)",
        precisions=frozenset({(1, 1)}),
        families=frozenset({"scores"}),
        traffic_model=lambda m, k, n, ab, wb: 4 * (m * k + n * k) + 4 * m * n,
    )
)
