"""jit'd wrappers around the Pallas kernels: padding, dispatch, epilogues.

Public entry points take logical (unpadded) shapes, pad to kernel block
multiples, invoke the kernel, slice back, and (for the QuantTensor entry)
apply the flow-abstraction epilogue.  ``interpret`` defaults to
auto-detection: real kernels on TPU, interpret mode elsewhere — the same
switch the model layer uses.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import flow_abstraction, packing, quantization
from repro.core.quantization import QuantTensor
from repro.kernels import binary_qmm as _bq
from repro.kernels import bitserial_qmm as _bs
from repro.kernels import popcount_qmm as _pq

__all__ = [
    "on_tpu",
    "binary_qmm_int",
    "popcount_qmm_int",
    "bitserial_qmm_int",
    "qmm_pallas",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret: Optional[bool]) -> bool:
    return (not on_tpu()) if interpret is None else interpret


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def binary_qmm_int(
    a: jax.Array,
    w_packed: jax.Array,
    k: int,
    *,
    block=_bq.DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``a (M, K) int8 @ unpack(w_packed) (K, N)`` with auto-padding.

    Zero padding is exact: padded activation columns hit padded (zero) weight
    rows; padded rows/cols are sliced off.
    """
    bm, bn, bk = block
    m, _ = a.shape
    n = w_packed.shape[1]
    a_p = _pad_to(_pad_to(a, 0, bm), 1, bk)
    kp = a_p.shape[1]
    # pad packed weights along words to kp/32, then columns to bn
    w_p = _pad_to(_pad_to(w_packed, 0, kp // 32), 1, bn)
    out = _bq.binary_qmm(
        a_p, w_p, k=kp, block=block, interpret=_auto_interpret(interpret)
    )
    return out[:m, :n]


def popcount_qmm_int(
    a_packed: jax.Array,
    b_packed: jax.Array,
    *,
    block=_pq.DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Binary x binary over packed operands with auto-padding (M, N, Kw)."""
    bm, bn, bkw = block
    m, kw = a_packed.shape
    n = b_packed.shape[1]
    a_p = _pad_to(_pad_to(a_packed, 0, bm), 1, bkw)
    b_p = _pad_to(_pad_to(b_packed, 0, a_p.shape[1]), 1, bn)
    out = _pq.popcount_qmm(a_p, b_p, block=block, interpret=_auto_interpret(interpret))
    return out[:m, :n]


def bitserial_qmm_int(
    a_planes: jax.Array,
    b_planes: jax.Array,
    *,
    block=_bs.DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Multi-bit act x act from packed planes with auto-padding."""
    bm, bn, bkw = block
    _, m, kw = a_planes.shape
    n = b_planes.shape[2]
    a_p = _pad_to(_pad_to(a_planes, 1, bm), 2, bkw)
    b_p = _pad_to(_pad_to(b_planes, 1, a_p.shape[2]), 2, bn)
    out = _bs.bitserial_qmm(a_p, b_p, block=block, interpret=_auto_interpret(interpret))
    return out[:m, :n]


def qmm_pallas(
    x: QuantTensor,
    w: QuantTensor,
    *,
    w_colsum: Optional[jax.Array] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """QuantTensor QMM routed through the Pallas kernels + flow epilogue.

    Dispatch (mirrors BETA's mode table, Fig. 4):
      * weight_bits == 1 and act mantissa int8-representable -> binary_qmm
        (fused unpack + MXU int8) — act x weight, any act precision.
      * 1-bit x 1-bit -> popcount_qmm on fully packed operands.
      * multi-bit act x act -> bitserial_qmm over bit-planes.

    Only rank-2 operands hit the kernels; callers flatten leading batch dims
    (the model layer does).  Falls back to the jnp paths for other cases.
    """
    x_l = x.logical_shape
    w_l = w.logical_shape
    if len(w_l) != 2 or len(x_l) != 2:
        raise ValueError("qmm_pallas expects rank-2 operands; flatten batch dims")
    k = x_l[-1]

    if x.bits == 1 and w.bits == 1:
        a_packed = (
            x.mantissa if x.packed else packing.pack_bits(x.mantissa, 1, axis=-1)
        )
        b_packed = (
            w.mantissa if w.packed else packing.pack_bits(w.mantissa, 1, axis=0)
        )
        xy = popcount_qmm_int(a_packed, b_packed, interpret=interpret)
        return _epilogue(x, w, xy, k, w_colsum, out_dtype)

    if w.bits == 1:
        # act x weight: re-center activations (exact), unpack to int8.
        xr = quantization.recenter(x)
        a8 = xr.unpack(dtype=jnp.int8).mantissa
        b_packed = (
            w.mantissa if w.packed else packing.pack_bits(w.mantissa, 1, axis=0)
        )
        xy = binary_qmm_int(a8, b_packed, k, interpret=interpret)
        return _epilogue(xr, w, xy, k, w_colsum, out_dtype)

    # multi-bit act x act: bit-serial planes (unsigned mantissas).
    a_planes = packing.pack_bitplanes(
        x.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), x.bits, axis=-1
    )
    b_planes = packing.pack_bitplanes(
        w.unpack(dtype=jnp.int32).mantissa.astype(jnp.uint32), w.bits, axis=-2
    )
    xy = bitserial_qmm_int(a_planes, b_planes, interpret=interpret)
    return _epilogue(x, w, xy, k, w_colsum, out_dtype)


def _epilogue(x, w, xy, k, w_colsum, out_dtype):
    """Flow-abstraction corrections on the kernel's integer MM output.

    Valid for any mantissa representation (signed/unsigned) because the
    affine identity holds verbatim — re-centering only moves the offsets.
    ``w_colsum``, when provided, must be the colsum of the mantissas exactly
    as the kernel consumed them (weight_corrections() handles this).
    """
    x1 = x.unpack(dtype=jnp.int32).mantissa
    a1 = jnp.asarray(x.scale, out_dtype)
    g1 = jnp.asarray(x.offset, out_dtype)
    a2 = jnp.asarray(w.scale, out_dtype)
    g2 = jnp.asarray(w.offset, out_dtype)
    out = xy.astype(out_dtype) * (a1 * a2)
    row = jnp.sum(x1, axis=-1, dtype=jnp.int32)[..., None].astype(out_dtype)
    out = out + (a1 * g2) * row
    col = (
        w_colsum
        if w_colsum is not None
        else jnp.sum(w.unpack(dtype=jnp.int32).mantissa, axis=-2, dtype=jnp.int32)
    )
    out = out + (g1 * a2) * col[..., None, :].astype(out_dtype)
    return out + g1 * g2 * jnp.asarray(k, out_dtype)
