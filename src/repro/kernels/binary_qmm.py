"""Pallas TPU kernel: fused unpack -> MXU integer dot (BETA's QMM engine).

The TPU-native adaptation of BETA's DPU (DESIGN.md §2): binary weights stay
**bit-packed in HBM** (1/16th the bf16 footprint — the memory-roofline win),
are unpacked to int8 inside VMEM, and the MAC work runs on the MXU's 8-bit
integer datapath (~2x bf16 rate) instead of an FPGA XNOR/popcount fabric.

Blocking (BlockSpec):
  grid = (M/bm, N/bn, K/bk), K innermost so the fp32/int32 accumulator tile
  stays resident in VMEM across the K sweep (the Pallas analogue of the
  compressor-tree *loop* carrying partial sums; the final flush is the
  carry-select-adder step).

  A  (bm, bk)   int8   — quantized activation mantissas (re-centered)
  Wp (bk/32,bn) uint32 — packed binary weight mantissas {0,1}
  O  (bm, bn)   int32  — integer MM result (flow-abstraction epilogue is
                          applied outside, fused by XLA)

VMEM @ defaults (bm=bn=128, bk=512): A 64 KiB + Wp 8 KiB + unpacked W 64 KiB
+ acc 64 KiB ~= 200 KiB — comfortably within a v5e core's ~16 MiB VMEM and
MXU-aligned (every matmul dim a multiple of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["binary_qmm", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 512)  # bm, bn, bk
_LANES_PER_WORD = 32


def _kernel(a_ref, wp_ref, o_ref, *, bk: int):
    """One (bm, bn) tile x one bk-slice of the reduction."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # --- fused unpack: (bk/32, bn) uint32 -> (bk, bn) int8 {0,1} ---
    wp = wp_ref[...]
    shifts = jnp.arange(_LANES_PER_WORD, dtype=jnp.uint32)[None, :, None]
    w_bits = (wp[:, None, :] >> shifts) & jnp.uint32(1)
    w = w_bits.reshape(bk, wp.shape[-1]).astype(jnp.int8)

    # --- MXU integer MAC, int32 accumulation (compressor-tree analogue) ---
    a = a_ref[...]
    o_ref[...] += jax.lax.dot_general(
        a,
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(
    jax.jit, static_argnames=("k", "block", "interpret")
)
def binary_qmm(
    a: jax.Array,
    w_packed: jax.Array,
    *,
    k: int,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Integer MM ``a @ unpack(w_packed)`` with binary packed weights.

    Args:
      a: int8 ``(M, K)`` quantized activation mantissas.
      w_packed: uint32 ``(K/32, N)`` bit-packed binary weight mantissas.
      k: logical K (must equal ``a.shape[1]``; multiple of 32 and of
        ``block[2]`` — callers pad via ``ops.binary_qmm_int``).
      block: (bm, bn, bk) VMEM tile sizes.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns:
      int32 ``(M, N)``.
    """
    m, ak = a.shape
    kw, n = w_packed.shape
    bm, bn, bk = block
    if ak != k or kw * _LANES_PER_WORD != k:
        raise ValueError(f"K mismatch: a {a.shape}, w_packed {w_packed.shape}, k={k}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shapes ({m},{k},{n}) not multiples of block {block}")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // _LANES_PER_WORD, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, w_packed)
