"""Pallas TPU kernels for the QMM hot-spots (validated in interpret mode).

- ``binary_qmm``    fused unpack -> MXU int8 dot (the default TPU datapath)
- ``popcount_qmm``  AND+popcount on packed words (faithful DPU analogue)
- ``bitserial_qmm`` multi-bit act x act over bit-planes (Fig. 4 schedule)
- ``fused_qmm``     whole bit-serial schedule + affine epilogue in one kernel
- ``ops``           jit'd wrappers: padding, dispatch, flow epilogue
- ``ref``           pure-jnp oracles (the correctness contracts)
"""

from repro.kernels import (
    binary_qmm,
    bitserial_qmm,
    fused_qmm,
    ops,
    popcount_qmm,
    ref,
)

__all__ = ["binary_qmm", "bitserial_qmm", "fused_qmm", "ops", "popcount_qmm", "ref"]
