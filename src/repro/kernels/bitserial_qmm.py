"""Pallas TPU kernel: bit-serial multi-bit act x act QMM (paper Fig. 4).

BETA runs a ``Wa x Aa`` activation x activation product by traversing one
operand bit-plane per cycle on the binary engine and shifting partial
results into place: ``A @ B = sum_ij 2^(i+j) (A_i (x) B_j)``.  This kernel is
that schedule with the planes unrolled inside one VMEM-resident block: the
(i, j) plane pairs reuse the same operand tiles, so packed bits are fetched
from HBM exactly once (the compute-buffer reuse idea of §III-C).

Blocking: grid = (M/bm, N/bn, Kw/bkw), K innermost; operand tiles carry the
plane axis whole (a_bits, b_bits <= 8, so worst case 8x8 = 64 plane pairs of
AND+popcount work per tile — still VPU-bound, as on BETA where the same pass
count shows up as `accumulation times`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitserial_qmm", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (64, 128, 32)  # bm, bn, bkw


def _kernel(a_ref, b_ref, o_ref, *, a_bits: int, b_bits: int, bkw: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for i in range(a_bits):  # static unroll: the bit-serial schedule
        a_i = a_ref[i]  # (bm, bkw) uint32
        for j in range(b_bits):
            b_j = b_ref[j]  # (bkw, bn) uint32

            def word_step(w, inner, a_i=a_i, b_j=b_j):
                aw = jax.lax.dynamic_slice_in_dim(a_i, w, 1, axis=1)
                bw = jax.lax.dynamic_slice_in_dim(b_j, w, 1, axis=0)
                joint = jnp.bitwise_and(aw, bw)
                return inner + jax.lax.population_count(joint).astype(jnp.int32)

            part = jax.lax.fori_loop(0, bkw, word_step, jnp.zeros(o_ref.shape, jnp.int32))
            acc = acc + (part << (i + j))
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bitserial_qmm(
    a_planes: jax.Array,
    b_planes: jax.Array,
    *,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Multi-bit integer MM from packed bit-planes.

    Args:
      a_planes: uint32 ``(a_bits, M, Kw)`` — left mantissa bit-planes,
        1-bit-packed along the last axis.
      b_planes: uint32 ``(b_bits, Kw, N)`` — right mantissa bit-planes,
        packed along axis -2.
      block: (bm, bn, bkw).
      interpret: CPU validation mode.

    Returns:
      int32 ``(M, N)`` == ``A @ B`` of the original multi-bit mantissas.
    """
    a_bits, m, kw = a_planes.shape
    b_bits, kw2, n = b_planes.shape
    if kw != kw2:
        raise ValueError(f"packed-K mismatch: {a_planes.shape} vs {b_planes.shape}")
    bm, bn, bkw = block
    if m % bm or n % bn or kw % bkw:
        raise ValueError(f"shapes ({m},{kw},{n}) not multiples of block {block}")

    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, b_bits=b_bits, bkw=bkw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_bits, bm, bkw), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((b_bits, bkw, bn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_planes, b_planes)
