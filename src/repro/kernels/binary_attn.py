"""Bitwise attention-scores core: AND-popcount over packed Q/K bit-planes.

Bitformer's XNOR-popcount similarity, expressed in the repo's unified
unsigned-mantissa form (see ``core.flow_abstraction``): with Q and K
elastically binarized to ``alpha * b + gamma`` (b in {0, 1}), the +-1
XNOR-popcount becomes {0, 1} AND-popcount and the affine epilogue —
applied by the caller in ``models.attention`` — restores the real-valued
score:

    scores = aq*ak * popcount(qb & kb)
           + aq*gk * rowsum(qb) + gq*ak * colsum(kb) + gq*gk * dh

This module is the integer core only (the "binary" entry of the scores
backend family): packed planes in, int32 counts out, lane-parallel jnp —
the rank-4 analogue of ``core.qmm.and_popcount_matmul``.  GQA head
expansion happens here via view reshapes (head ``h`` reads kv head
``h // (H/G)``), so the packed K planes are never materialized per query
head.

Zero tail bits in the last packed word are benign by construction: the Q
planes are packed fresh from {0,1} mantissas each call, so their tail bits
are zero and AND masks whatever the K tail holds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing

__all__ = ["binary_attn_scores_planes"]

# Key positions processed per popcount sweep; bounds the broadcast joint
# intermediate to t_chunk * (G * S') * dw words per batch element.
_T_CHUNK = 256


def binary_attn_scores_planes(
    q_planes: jax.Array, k_planes: jax.Array, *, dh: int
) -> jax.Array:
    """``out[b,h,s,t] = sum_d q[b,h,s,d] * k[b,h//g,t,d]`` for bits in {0,1}.

    Args:
      q_planes: uint32 ``(B, H, S, dw)`` — query bits, dh packed little-endian
        along the last axis (``dw = packed_len(dh, 1)``).
      k_planes: uint32 ``(B, G, T, dw)`` — key bits per kv head; H must be a
        multiple of G (GQA head expansion).
      dh: logical head dim (the packed length).

    Returns:
      int32 ``(B, H, S, T)`` AND-popcount counts.
    """
    if q_planes.dtype != jnp.uint32 or k_planes.dtype != jnp.uint32:
        raise TypeError(
            "binary_attn_scores_planes: operands must be uint32 bit-planes, "
            f"got {q_planes.dtype} and {k_planes.dtype}"
        )
    if q_planes.ndim != 4 or k_planes.ndim != 4:
        raise ValueError(
            "binary_attn_scores_planes: operands must be rank 4, got "
            f"{q_planes.ndim} and {k_planes.ndim}"
        )
    dw = packing.packed_len(dh, 1)
    if q_planes.shape[-1] != dw or k_planes.shape[-1] != dw:
        raise ValueError(
            f"binary_attn_scores_planes: packed axis must hold "
            f"ceil({dh}/32) = {dw} words, got {q_planes.shape[-1]} "
            f"and {k_planes.shape[-1]}"
        )
    b, h, s, _ = q_planes.shape
    g, t = k_planes.shape[1], k_planes.shape[2]
    if h % g:
        raise ValueError(
            f"binary_attn_scores_planes: H={h} not a multiple of G={g}"
        )
    # Fold the per-kv-head query group onto the row axis: each kv head's
    # packed planes are popcounted against all of its group's queries in one
    # lane-parallel sweep.
    qg = q_planes.reshape(b, g, (h // g) * s, dw)
    out_chunks = []
    for t0 in range(0, t, _T_CHUNK):
        k_blk = jax.lax.slice_in_dim(k_planes, t0, min(t0 + _T_CHUNK, t), axis=2)
        # (B, G, M, 1, dw) & (B, G, 1, Tc, dw) -> popcount -> sum over dw.
        joint = qg[:, :, :, None, :] & k_blk[:, :, None, :, :]
        out_chunks.append(
            jnp.sum(jax.lax.population_count(joint).astype(jnp.int32), axis=-1)
        )
    out = (
        jnp.concatenate(out_chunks, axis=-1) if len(out_chunks) > 1 else out_chunks[0]
    )
    return out.reshape(b, h, s, t)
