"""Pallas TPU kernel: the *fused* bit-serial QMM — integer core + epilogue.

This is the paper's datapath (§III-A/III-C, Fig. 4) end-to-end in one kernel.
The staged Pallas paths (``binary_qmm``/``bitserial_qmm``/``popcount_qmm``)
return an integer MM to HBM and apply the flow-abstraction epilogue as a
separate XLA computation; here the whole schedule runs inside one grid pass:

* packed weight bit-planes stay resident in VMEM across the K traversal of a
  tile while activation bit-planes stream through the AND-popcount lanes;
* cross-plane accumulation ``sum_ij 2^(i+j) popcount(X_i & W_j)`` lives in an
  int32 VMEM accumulator ref, never touching HBM;
* the rank-1 flow-abstraction corrections need only ``rowsum(X)`` and
  ``colsum(W)``, and both are popcounts of the same planes already on chip —
  so they are accumulated in two narrow scratch refs alongside the MM;
* at the last K step the affine epilogue
  ``acc*(a1*a2) + (a1*g2)*row + (g1*a2)*col + g1*g2*K`` runs on the VPU and
  the fp32 result is the only thing written to HBM.

Operands are **raw unsigned mantissas** as bit-planes (the popcount contract:
no re-centering; the affine identity absorbs the representation).

Exactness contract vs ``kernels.ref.fused_qmm_ref``: the integer core (MM,
rowsum, colsum accumulators) is bit-exact, always.  The fp32 epilogue is
evaluated in the oracle's exact expression order, but compiled fp32 mul+add
chains may be contracted to fma (XLA:CPU does this and
``optimization_barrier`` does not prevent it), so epilogue equality across
two compilations is only *defined* when the arithmetic is exact: with
dyadic (power-of-two) scales whose offsets are dyadic multiples, every term
and partial sum is exactly representable and the kernel matches the oracle
bit-for-bit — that is the tested contract.  Arbitrary scales agree to
last-ulp fma-vs-mul/add differences.

Interpret mode runs the same kernel through the Pallas interpreter off-TPU
(CI's correctness fallback, same switch as the other kernels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_qmm", "DEFAULT_BLOCK"]

# bm, bn, bkw (words of 32 K-bits): 16 words = 512 logical K per step keeps
# the padded-K floor low for ragged shapes while the plane tiles stay small
# enough that an 8x8-plane worst case still fits VMEM comfortably.
DEFAULT_BLOCK = (64, 128, 16)


def _kernel(
    a_ref,
    b_ref,
    a_scale_ref,
    a_off_ref,
    w_scale_ref,
    w_off_ref,
    o_ref,
    acc_ref,
    row_ref,
    col_ref,
    *,
    a_bits: int,
    b_bits: int,
    bkw: int,
    k_logical: int,
):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        row_ref[...] = jnp.zeros_like(row_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    acc = jnp.zeros(acc_ref.shape, jnp.int32)
    row = jnp.zeros(row_ref.shape, jnp.int32)
    col = jnp.zeros(col_ref.shape, jnp.int32)
    for i in range(a_bits):  # static unroll: the bit-serial schedule
        a_i = a_ref[i]  # (bm, bkw) uint32
        # rowsum(X) = sum_i 2^i * popcount(plane i) — same bits, no extra HBM.
        row = row + (
            jnp.sum(
                jax.lax.population_count(a_i).astype(jnp.int32),
                axis=1,
                keepdims=True,
            )
            << i
        )
        for j in range(b_bits):
            b_j = b_ref[j]  # (bkw, bn) uint32
            if i == 0:
                col = col + (
                    jnp.sum(
                        jax.lax.population_count(b_j).astype(jnp.int32),
                        axis=0,
                        keepdims=True,
                    )
                    << j
                )

            def word_step(w, inner, a_i=a_i, b_j=b_j):
                aw = jax.lax.dynamic_slice_in_dim(a_i, w, 1, axis=1)
                bw = jax.lax.dynamic_slice_in_dim(b_j, w, 1, axis=0)
                joint = jnp.bitwise_and(aw, bw)
                return inner + jax.lax.population_count(joint).astype(jnp.int32)

            part = jax.lax.fori_loop(
                0, bkw, word_step, jnp.zeros(acc_ref.shape, jnp.int32)
            )
            acc = acc + (part << (i + j))
    acc_ref[...] += acc
    row_ref[...] += row
    col_ref[...] += col

    # Fused affine epilogue (flow abstraction, §III-A): runs once per (i, j)
    # tile, after the last K slab; fp32 out is the only HBM write.
    @pl.when(kk == pl.num_programs(2) - 1)
    def _epilogue():
        a1 = a_scale_ref[...]  # (bm, 1) f32
        g1 = a_off_ref[...]
        a2 = w_scale_ref[...]  # (1, bn) f32
        g2 = w_off_ref[...]
        t0 = acc_ref[...].astype(jnp.float32) * (a1 * a2)
        t1 = (a1 * g2) * row_ref[...].astype(jnp.float32)
        t2 = (g1 * a2) * col_ref[...].astype(jnp.float32)
        t3 = g1 * g2 * jnp.float32(k_logical)
        o_ref[...] = ((t0 + t1) + t2) + t3


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def fused_qmm(
    a_planes: jax.Array,
    b_planes: jax.Array,
    a_scale: jax.Array,
    a_offset: jax.Array,
    w_scale: jax.Array,
    w_offset: jax.Array,
    *,
    k: int,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Fused bit-serial QMM: integer MM + affine epilogue in one kernel.

    Args:
      a_planes: uint32 ``(a_bits, M, Kw)`` — left *unsigned* mantissa
        bit-planes, 1-bit-packed along the last axis.
      b_planes: uint32 ``(b_bits, Kw, N)`` — right unsigned mantissa planes,
        packed along axis -2.
      a_scale / a_offset: fp32 ``(M, 1)`` per-row affine coefficients.
      w_scale / w_offset: fp32 ``(1, N)`` per-column affine coefficients.
      k: *logical* K (pre-padding) — the constant term uses the true
        reduction length; padded zero bits contribute nothing elsewhere.
      block: ``(bm, bn, bkw)``; all operand dims must be pre-padded to
        multiples (``repro.kernels.ops.qmm_fused`` handles padding).
      interpret: CPU validation mode.

    Returns:
      fp32 ``(M, N)`` — the full affine product
      ``(a1*X + g1)(a2*W + g2)`` evaluated via the flow abstraction.
    """
    a_bits, m, kw = a_planes.shape
    b_bits, kw2, n = b_planes.shape
    if kw != kw2:
        raise ValueError(f"packed-K mismatch: {a_planes.shape} vs {b_planes.shape}")
    if a_scale.shape != (m, 1) or a_offset.shape != (m, 1):
        raise ValueError(f"activation coefficients must be ({m}, 1)")
    if w_scale.shape != (1, n) or w_offset.shape != (1, n):
        raise ValueError(f"weight coefficients must be (1, {n})")
    bm, bn, bkw = block
    if m % bm or n % bn or kw % bkw:
        raise ValueError(f"shapes ({m},{kw},{n}) not multiples of block {block}")

    grid = (m // bm, n // bn, kw // bkw)
    coeff = jnp.float32
    return pl.pallas_call(
        functools.partial(
            _kernel, a_bits=a_bits, b_bits=b_bits, bkw=bkw, k_logical=int(k)
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_bits, bm, bkw), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((b_bits, bkw, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),  # cross-plane MM accumulator
            pltpu.VMEM((bm, 1), jnp.int32),  # rowsum(X)
            pltpu.VMEM((1, bn), jnp.int32),  # colsum(W)
        ],
        interpret=interpret,
    )(
        a_planes,
        b_planes,
        a_scale.astype(coeff),
        a_offset.astype(coeff),
        w_scale.astype(coeff),
        w_offset.astype(coeff),
    )
