"""Autotuned QMM backend dispatch — the measured half of the §III-C engine.

BETA's QMM engine is *configurable*: per precision mode it picks the
datapath (packed-parallel vs bit-serial) that the operands deserve.  The
software analogue is shape-dependent as well — which integer backend
(``mxu`` / ``popcount`` / ``pallas``) wins depends on ``(M, K, N)``, the
operand precisions, and what this host can actually run — so the right
dispatch policy is *measured*, not hardcoded.

This module provides :class:`AutotuneCache`:

* keyed on ``(M, K, N, act_bits, weight_bits, candidate set, phase tag)``;
  ``M`` is bucketed to the next power of two so serving waves with ragged
  prompt lengths share entries;
* on first miss it times every candidate backend on synthetic operands of
  the key's exact shape/precision (compile warmup, then ``reps`` timed
  calls under ``jax.block_until_ready``) and records the winner;
* thereafter the winner is served from the cache — including from inside
  ``jax.jit`` traces, where shapes are static and the eager timing run
  happens once at trace time;
* persists to JSON (:meth:`AutotuneCache.save` / :meth:`AutotuneCache.load`)
  so serving processes skip the warmup entirely.

``qmm(backend="auto")`` delegates here; prefill and decode run under
distinct :func:`tuning_phase` tags because their ``M`` differs by orders of
magnitude and the winner need not be the same backend.

Environment knobs:

* ``REPRO_QMM_AUTOTUNE=0``      — disable timing; "auto" resolves to "mxu".
* ``REPRO_QMM_AUTOTUNE_CACHE``  — JSON path auto-loaded into the default
  cache on first use (written back by ``ServeEngine`` when configured).

The cache-file format is documented in docs/qmm-engine.md.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import json
import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "BACKENDS",  # deprecated dynamic view; use backend_registry.backend_names()
    "DEFAULT_BACKEND",
    "DEFAULT_SCORES_BACKEND",
    "TuneKey",
    "TuneRecord",
    "AutotuneCache",
    "candidate_backends",
    "make_problem",
    "make_scores_problem",
    "choose_backend",
    "choose_scores_backend",
    "get_cache",
    "reset_cache",
    "tuning_phase",
    "current_phase",
    "pin_demotion",
    "clear_demotions",
    "demotions",
    "resolve_backend",
]

#: Fallback when autotuning is disabled or a cache entry is missing.
DEFAULT_BACKEND = "mxu"

#: Scores-family fallback: the packed AND-popcount core (always available,
#: bit-exact against every other scores core).
DEFAULT_SCORES_BACKEND = "binary"


def __getattr__(name: str) -> Tuple[str, ...]:
    # Deprecated: ``dispatch.BACKENDS`` predates the backend registry (and
    # backend *families*).  Every legacy call site reads it as "names valid
    # for ``QE.qmm``", so it is served dynamically (PEP 562) as the qmm
    # family; new code should call
    # ``repro.core.backend_registry.backend_names(family=...)`` directly.
    if name == "BACKENDS":
        from repro.core import backend_registry

        return backend_registry.backend_names(family="qmm")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


_CACHE_ENV = "REPRO_QMM_AUTOTUNE_CACHE"
_DISABLE_ENV = "REPRO_QMM_AUTOTUNE"

_PHASE: contextvars.ContextVar = contextvars.ContextVar("qmm_tuning_phase", default="")


def current_phase() -> str:
    """The active tuning tag ("" outside any :func:`tuning_phase` block)."""
    return _PHASE.get()


@contextlib.contextmanager
def tuning_phase(tag: str):
    """Scope a tuning tag (e.g. "prefill" / "decode") over qmm(auto) calls.

    Tags split the cache key: a decode-shaped QMM (M = batch) and a
    prefill-shaped one (M = batch * prompt) must never share a timing
    verdict even if bucketing would otherwise merge them.
    """
    token = _PHASE.set(tag)
    try:
        yield
    finally:
        _PHASE.reset(token)


# ---------------------------------------------------------------------------
# backend demotion (the serving degradation policy's dispatch hook)
# ---------------------------------------------------------------------------

# Process-wide demotion table: {failing backend -> known-good fallback}.
# Pinned by the serving engine when a backend fails repeatedly (e.g. the
# fused Pallas kernel refusing to lower off-TPU); consulted by ``qmm`` AFTER
# name resolution, so it overrides explicit config names, per-layer
# overrides, and autotune verdicts alike — the autotune cache itself is left
# untouched (a demotion is an availability fact, not a timing verdict).
_DEMOTIONS: Dict[str, str] = {}


def pin_demotion(src: str, dst: str) -> None:
    """Route every dispatch of ``src`` to ``dst`` for this process.

    Both names must be registered; pinning a cycle (``dst`` already resolving
    back to ``src``) is rejected — a demotion chain must terminate.
    """
    from repro.core import backend_registry

    known = set(backend_registry.backend_names())
    for name in (src, dst):
        if name not in known:
            raise ValueError(
                f"cannot pin demotion {src!r} -> {dst!r}: unknown backend {name!r}"
            )
    if src == dst or resolve_backend(dst) == src:
        raise ValueError(f"demotion {src!r} -> {dst!r} would form a cycle")
    _DEMOTIONS[src] = dst


def clear_demotions() -> None:
    """Drop every pinned demotion (tests; operator-driven re-promotion)."""
    _DEMOTIONS.clear()


def demotions() -> Dict[str, str]:
    """A copy of the active demotion table."""
    return dict(_DEMOTIONS)


def resolve_backend(name: str) -> str:
    """Follow the demotion chain from ``name`` to its serving backend."""
    seen = set()
    while name in _DEMOTIONS and name not in seen:
        seen.add(name)
        name = _DEMOTIONS[name]
    return name


def _bucket_m(m: int) -> int:
    """Round M up to a power of two (>= 8) so ragged serving waves share
    cache entries instead of re-tuning per prompt length."""
    b = 8
    while b < m:
        b <<= 1
    return b


def candidate_backends(
    m: int,
    k: int,
    n: int,
    act_bits: int,
    weight_bits: int,
    *,
    rank2: bool = True,
    family: str = "qmm",
) -> Tuple[str, ...]:
    """Backends eligible for this problem on this host (the "availability"
    component of the cache key) — enumerated from the backend registry, so
    a newly registered backend becomes an autotune candidate with zero
    dispatcher edits."""
    from repro.core import backend_registry  # lazy: keeps core import-light

    return backend_registry.candidate_names(
        m, k, n, act_bits, weight_bits, rank2=rank2, family=family
    )


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """One autotune cell. ``m`` is bucketed; ``candidates`` captures backend
    availability so a cache file moved across hosts never serves a backend
    the new host would not have timed."""

    m: int
    k: int
    n: int
    act_bits: int
    weight_bits: int
    candidates: Tuple[str, ...]
    tag: str = ""
    #: Operator family: "qmm" (rank-2 matmul) or "scores" (rank-4 attention
    #: scores, m = B*H*S, k = dh, n = T).  Families never share entries.
    family: str = "qmm"


@dataclasses.dataclass
class TuneRecord:
    backend: str
    timings_us: Dict[str, float]
    timed: bool  # False when forced, single-candidate, or autotune disabled
    # Every timing probe raised: the record is an in-process fallback only —
    # never persisted, so the next process re-times instead of inheriting a
    # transient failure as a permanent verdict.
    failed: bool = False


def make_problem(key: TuneKey):
    """Synthetic operands matching the key, in the layout serving uses.

    weight_bits == 1 (act x weight): sign-binarized weights, BIT-PACKED with
    a precomputed colsum — exactly what ``pack_linear_for_serving`` feeds
    the engine; timing unpacked weights would measure a problem production
    never runs.  Multi-bit right operands are act x act and stay unpacked,
    as the attention path quantizes them on the fly."""
    from repro.core import flow_abstraction as FA
    from repro.core import quantization as Q

    rng = np.random.default_rng(
        (key.m * 1000003 + key.k * 10007 + key.n * 101 + key.act_bits * 7 + key.weight_bits)
        % (2**32)
    )
    import jax.numpy as jnp

    x = jnp.asarray(rng.standard_normal((key.m, key.k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((key.k, key.n)).astype(np.float32))
    xq = Q.quantize_activation(x, key.act_bits)
    wq = Q.quantize_weight(w, key.weight_bits)
    colsum = None
    if key.weight_bits == 1:
        colsum = FA.weight_corrections(wq)
        wq = wq.pack(axis=0)
    return xq, wq, colsum


def make_scores_problem(key: TuneKey):
    """Synthetic packed Q/K bit-planes for one scores-family key.

    The key folds ``B*H*S`` into ``m``, ``dh`` into ``k`` and ``T`` into
    ``n``; timing collapses the batch/head dims to 1 and puts the whole
    ``m`` on the S axis — the popcount/MXU cores are lane-parallel over
    rows, so the timing is representative of any (B, H, S) split with the
    same product."""
    from repro.core import packing

    import jax.numpy as jnp

    rng = np.random.default_rng(
        (key.m * 1000003 + key.k * 10007 + key.n * 101 + 5) % (2**32)
    )
    q_bits = rng.integers(0, 2, size=(1, 1, key.m, key.k), dtype=np.uint8)
    k_bits = rng.integers(0, 2, size=(1, 1, key.n, key.k), dtype=np.uint8)
    q_planes = packing.pack_bits(jnp.asarray(q_bits), 1, axis=-1)
    k_planes = packing.pack_bits(jnp.asarray(k_bits), 1, axis=-1)
    return q_planes, k_planes


def _wallclock_timer(fn: Callable[[], object], *, warmup: int = 1, reps: int = 3) -> float:
    """Best-of-``reps`` wall-clock of ``fn`` in seconds, after compile warmup.

    Min, not mean: on a contended host the minimum is the robust estimator
    of a kernel's intrinsic cost (contention only ever adds time)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


class AutotuneCache:
    """Shape/precision-keyed backend choice, measured once per key.

    ``timer`` is injectable (tests pass a deterministic fake); the default
    times real jitted calls.  ``timing_runs`` counts individual backend
    probes — a loaded cache must not grow it.
    """

    def __init__(
        self,
        *,
        timer: Optional[Callable[[Callable[[], object]], float]] = None,
        warmup: int = 1,
        reps: int = 3,
    ):
        self._entries: Dict[TuneKey, TuneRecord] = {}
        self._timer = timer or functools.partial(
            _wallclock_timer, warmup=warmup, reps=reps
        )
        self.timing_runs = 0

    # -- lookup --------------------------------------------------------------

    def choose(
        self,
        m: int,
        k: int,
        n: int,
        act_bits: int,
        weight_bits: int,
        *,
        tag: Optional[str] = None,
        rank2: bool = True,
        family: str = "qmm",
    ) -> str:
        """The winning backend for this problem (timing on first miss)."""
        mb = _bucket_m(int(m))
        key = TuneKey(
            mb,
            int(k),
            int(n),
            int(act_bits),
            int(weight_bits),
            candidate_backends(
                mb, k, n, act_bits, weight_bits, rank2=rank2, family=family
            ),
            current_phase() if tag is None else tag,
            family,
        )
        rec = self._entries.get(key)
        if rec is None:
            rec = self._tune(key)
            self._entries[key] = rec
        return rec.backend

    def record(self, key: TuneKey) -> Optional[TuneRecord]:
        return self._entries.get(key)

    @property
    def entries(self) -> Dict[TuneKey, TuneRecord]:
        return dict(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    # -- timing --------------------------------------------------------------

    def _tune(self, key: TuneKey) -> TuneRecord:
        if len(key.candidates) == 1:
            return TuneRecord(key.candidates[0], {}, False)
        if key.family == "scores":
            return self._tune_scores(key)
        from repro.core import qmm as QE

        xq, wq, colsum = make_problem(key)
        timings: Dict[str, float] = {}
        for b in key.candidates:
            call = jax.jit(
                functools.partial(QE.qmm, backend=b, w_colsum=colsum)
            )
            try:
                timings[b] = self._timer(lambda c=call: c(xq, wq))
            except Exception:  # noqa: BLE001 — a failing backend just loses
                continue
            self.timing_runs += 1
        if not timings:
            return TuneRecord(DEFAULT_BACKEND, {}, False, failed=True)
        best = min(timings, key=timings.get)
        return TuneRecord(best, {b: t * 1e6 for b, t in timings.items()}, True)

    def _tune_scores(self, key: TuneKey) -> TuneRecord:
        """Scores-family timing: each candidate's ``run_scores`` over the
        same packed planes.  All scores cores are bit-exact against
        ``ref.binary_attn_scores_ref``, so the winner is purely a speed
        verdict — numerics (and batch invariance) don't depend on it."""
        from repro.core import backend_registry

        q_planes, k_planes = make_scores_problem(key)
        timings: Dict[str, float] = {}
        for b in key.candidates:
            spec = backend_registry.get_backend(b)
            call = jax.jit(functools.partial(spec.run_scores, dh=key.k))
            try:
                timings[b] = self._timer(lambda c=call: c(q_planes, k_planes))
            except Exception:  # noqa: BLE001 — a failing backend just loses
                continue
            self.timing_runs += 1
        if not timings:
            return TuneRecord(DEFAULT_SCORES_BACKEND, {}, False, failed=True)
        best = min(timings, key=timings.get)
        return TuneRecord(best, {b: t * 1e6 for b, t in timings.items()}, True)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "version": 1,
            "entries": [
                {
                    "m": k.m,
                    "k": k.k,
                    "n": k.n,
                    "act_bits": k.act_bits,
                    "weight_bits": k.weight_bits,
                    "candidates": list(k.candidates),
                    "tag": k.tag,
                    "family": k.family,
                    "backend": r.backend,
                    "timings_us": r.timings_us,
                    "timed": r.timed,
                }
                for k, r in self._entries.items()
                if not r.failed
            ],
        }

    def save(self, path: str) -> None:
        """Atomic JSON dump (write + rename) of every tuned entry."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)

    def load(self, path: str) -> int:
        """Merge entries from ``path``; returns how many were loaded.

        Entries naming a backend this build does not know are skipped (a
        cache file is advice, never an obligation)."""
        with open(path) as f:
            blob = json.load(f)
        if blob.get("version") != 1:
            raise ValueError(f"unsupported autotune cache version in {path}")
        from repro.core import backend_registry

        known = set(backend_registry.backend_names())
        loaded = 0
        for e in blob.get("entries", ()):
            if e["backend"] not in known:
                continue
            key = TuneKey(
                int(e["m"]),
                int(e["k"]),
                int(e["n"]),
                int(e["act_bits"]),
                int(e["weight_bits"]),
                tuple(e["candidates"]),
                e.get("tag", ""),
                e.get("family", "qmm"),
            )
            self._entries[key] = TuneRecord(
                e["backend"], dict(e.get("timings_us", {})), bool(e.get("timed"))
            )
            loaded += 1
        return loaded


# ---------------------------------------------------------------------------
# module-level default cache (what qmm(backend="auto") consults)
# ---------------------------------------------------------------------------

_default_cache: Optional[AutotuneCache] = None


def get_cache() -> AutotuneCache:
    """The process-wide cache, auto-loading ``$REPRO_QMM_AUTOTUNE_CACHE``."""
    global _default_cache
    if _default_cache is None:
        _default_cache = AutotuneCache()
        path = os.environ.get(_CACHE_ENV)
        if path and os.path.exists(path):
            _default_cache.load(path)
    return _default_cache


def reset_cache(cache: Optional[AutotuneCache] = None) -> AutotuneCache:
    """Swap the default cache (tests; serving with a preloaded cache)."""
    global _default_cache
    _default_cache = cache if cache is not None else AutotuneCache()
    return _default_cache


def autotune_enabled() -> bool:
    return os.environ.get(_DISABLE_ENV, "1").lower() not in ("0", "off", "false")


def choose_backend(
    m: int,
    k: int,
    n: int,
    act_bits: int,
    weight_bits: int,
    *,
    tag: Optional[str] = None,
    rank2: bool = True,
    cache: Optional[AutotuneCache] = None,
) -> str:
    """Resolve "auto" for one QMM problem (the core.qmm entry point).

    The returned name has demotions applied: a demoted backend's cached
    timing verdict survives (re-promotion needs no re-timing) but is never
    served while the pin is active.
    """
    if not autotune_enabled():
        return resolve_backend(DEFAULT_BACKEND)
    return resolve_backend(
        (cache or get_cache()).choose(
            m, k, n, act_bits, weight_bits, tag=tag, rank2=rank2
        )
    )


def choose_scores_backend(
    b: int,
    h: int,
    s: int,
    t: int,
    dh: int,
    *,
    tag: Optional[str] = None,
    cache: Optional[AutotuneCache] = None,
) -> str:
    """Resolve the scores-family core for one attention-scores problem.

    Keys on ``m = B*H*S`` (bucketed), ``k = dh``, ``n = T`` under the
    "scores" family, W1A1 by construction.  Demotions apply to the returned
    name exactly like qmm dispatch — ``pin_demotion("binary", "mxu")``
    reroutes the popcount core to the MXU core without changing numerics
    (every scores core is bit-exact against the ref oracle).
    """
    if not autotune_enabled():
        return resolve_backend(DEFAULT_SCORES_BACKEND)
    return resolve_backend(
        (cache or get_cache()).choose(
            int(b) * int(h) * int(s), dh, t, 1, 1, tag=tag, family="scores"
        )
    )
