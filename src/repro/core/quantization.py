"""Affine quantization for binary Transformers (BiT / BinaryBERT / BiBERT style).

The paper's operand model (§III-A): every QMM operand is ``alpha * x + gamma``
with full-precision coefficient ``alpha``, offset ``gamma`` and an unsigned
n-bit integer mantissa ``x``.  This module provides:

* :class:`QuantTensor` — a pytree carrying ``(mantissa, scale, offset, bits)``,
  optionally bit-packed along its reduction axis.
* quantizers — sign binarization with XNOR-Net/BiT per-channel scales for
  weights, elastic affine quantization for activations, both with
  straight-through estimators so the same code path serves QAT training.

Mantissa convention: unsigned ``x in [0, 2**bits)``.  Sign binarization
``w_hat = alpha * sign(w)`` is expressed as ``scale=2*alpha, offset=-alpha``,
mantissa ``(sign(w)+1)/2 in {0,1}`` — this keeps one unified affine form for
every precision and both QMM operand types, exactly the paper's abstraction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing

__all__ = [
    "QuantTensor",
    "ste_round",
    "quantize_activation",
    "binarize_weight",
    "quantize_weight",
    "dequantize",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantTensor:
    """An affine-quantized tensor ``alpha * x + gamma``.

    Attributes:
      mantissa: unsigned integer mantissa. If ``packed`` is set, dtype is
        uint32 and the ``packed_axis`` dim holds ``ceil(L / (32//bits))``
        words; otherwise an int8/int32 array of logical shape.
      scale: ``alpha`` — scalar () or per-channel (broadcastable to the
        *output* of dequantize).
      offset: ``gamma`` — scalar () or per-channel.
      bits: mantissa width (static).
      packed: whether ``mantissa`` is bit-packed (static).
      packed_axis: axis that was packed (static; conventionally the reduction
        dim of the QMM this tensor feeds).
      length: logical length of the packed axis (static; needed to unpack).
    """

    mantissa: jax.Array
    scale: jax.Array
    offset: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    packed: bool = dataclasses.field(default=False, metadata=dict(static=True))
    packed_axis: int = dataclasses.field(default=-1, metadata=dict(static=True))
    length: Optional[int] = dataclasses.field(default=None, metadata=dict(static=True))

    @property
    def logical_shape(self) -> tuple:
        if not self.packed:
            return self.mantissa.shape
        shape = list(self.mantissa.shape)
        shape[self.packed_axis] = self.length
        return tuple(shape)

    def unpack(self, dtype=jnp.int32) -> "QuantTensor":
        """Return an unpacked view (no-op if already unpacked)."""
        if not self.packed:
            return self
        m = packing.unpack_bits(
            self.mantissa, self.bits, self.length, axis=self.packed_axis, dtype=dtype
        )
        return dataclasses.replace(
            self, mantissa=m, packed=False, packed_axis=-1, length=None
        )

    def pack(self, axis: int) -> "QuantTensor":
        """Bit-pack the mantissa along ``axis`` (reduction dim by convention)."""
        if self.packed:
            return self
        m = packing.pack_bits(self.mantissa, self.bits, axis=axis)
        return dataclasses.replace(
            self,
            mantissa=m,
            packed=True,
            packed_axis=axis,
            length=self.mantissa.shape[axis],
        )

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        x = self.unpack().mantissa.astype(dtype)
        return x * self.scale.astype(dtype) + self.offset.astype(dtype)


def dequantize(q: QuantTensor, dtype=jnp.float32) -> jax.Array:
    return q.dequantize(dtype)


def recenter(q: QuantTensor) -> QuantTensor:
    """Shift an unsigned mantissa to the signed range (exact, affine-absorbed).

    ``alpha*x + gamma == alpha*(x - c) + (gamma + alpha*c)`` with
    ``c = 2**(bits-1)``.  After the shift every mantissa fits int8, so the MXU
    integer path applies for all supported precisions, and worst-case int32
    accumulator growth drops 4x.  1-bit operands pass through unchanged (the
    packed {0,1} form feeds the popcount/bit-packed kernels directly).
    """
    if q.bits <= 1:
        return q
    c = 2 ** (q.bits - 1)
    m = q.unpack(dtype=jnp.int32).mantissa - c
    return dataclasses.replace(
        q,
        mantissa=m.astype(jnp.int8),
        offset=q.offset + q.scale * c,
        packed=False,
        packed_axis=-1,
        length=None,
    )


def ste_round(x: jax.Array) -> jax.Array:
    """Round with a straight-through gradient (identity backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _ste_clip(x: jax.Array, lo, hi) -> jax.Array:
    """Clip whose gradient is 1 inside [lo, hi] and 0 outside (standard QAT)."""
    return jnp.clip(x, lo, hi)


def quantize_activation(
    x: jax.Array,
    bits: int,
    scale: Optional[jax.Array] = None,
    offset: Optional[jax.Array] = None,
    per_channel_axis: Optional[int] = None,
) -> QuantTensor:
    """Elastic affine activation quantization (BiT §3.2).

    ``q = round(clip((x - gamma) / alpha, 0, 2**bits - 1))``; dequantized value
    is ``alpha * q + gamma``.  ``alpha``/``gamma`` may be learned parameters
    (passed in) or derived from the batch statistics (calibration mode) when
    omitted.  Gradients flow to ``x`` (STE through round/clip) and, when they
    are traced parameters, to ``scale``/``offset`` as in learned step-size
    quantization.

    Args:
      x: activations (any float dtype).
      bits: target precision (1, 2, 4, 8).
      scale: optional alpha. Derived as ``(max-min)/(2**bits-1)`` if None.
      offset: optional gamma. Derived as ``min`` if None.
      per_channel_axis: if given, calibration statistics are taken per this
        axis (kept); otherwise per-tensor.
    """
    qmax = float(2**bits - 1)
    if scale is None or offset is None:
        if per_channel_axis is None:
            reduce_axes = tuple(range(x.ndim))
            keepdims = False  # scalar stats broadcast against any rank
        else:
            axis = per_channel_axis % x.ndim
            reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
            keepdims = True
        x_det = jax.lax.stop_gradient(x)
        lo = jnp.min(x_det, axis=reduce_axes, keepdims=keepdims)
        hi = jnp.max(x_det, axis=reduce_axes, keepdims=keepdims)
        derived_scale = jnp.maximum((hi - lo) / qmax, 1e-8)
        scale = derived_scale if scale is None else scale
        offset = lo if offset is None else offset
    scale = jnp.asarray(scale, x.dtype)
    offset = jnp.asarray(offset, x.dtype)
    q_float = ste_round(_ste_clip((x - offset) / scale, 0.0, qmax))
    mantissa = jax.lax.stop_gradient(q_float).astype(jnp.uint8 if bits <= 8 else jnp.int32)
    return QuantTensor(mantissa=mantissa, scale=scale, offset=offset, bits=bits)


def binarize_weight(w: jax.Array, per_channel_axis: int = -1) -> QuantTensor:
    """Sign binarization with analytic optimal scale (XNOR-Net / BiT).

    ``w_hat = alpha * sign(w)`` with ``alpha = mean(|w|)`` reduced over the
    *reduction* dim (axis -2) only — per-out-channel for 2D ``(K, N)``
    weights and per-(expert, out-channel) for stacked ``(E, K, N)`` MoE
    weights.  Expressed in the unified affine form: mantissa
    ``(sign(w)+1)/2 in {0,1}``, ``scale = 2*alpha``, ``offset = -alpha``.
    """
    del per_channel_axis  # kept for API compat; scale is always per axis -2
    alpha = jnp.mean(jnp.abs(jax.lax.stop_gradient(w)), axis=-2, keepdims=True)
    alpha = jnp.maximum(alpha, 1e-8)
    bit = (jax.lax.stop_gradient(jnp.sign(w)) >= 0).astype(jnp.uint8)
    return QuantTensor(mantissa=bit, scale=2.0 * alpha, offset=-alpha, bits=1)


def quantize_weight(w: jax.Array, bits: int, per_channel_axis: int = -1) -> QuantTensor:
    """n-bit symmetric-range affine weight quantization (binary when bits=1)."""
    if bits == 1:
        return binarize_weight(w, per_channel_axis)
    return quantize_activation(w, bits, per_channel_axis=per_channel_axis)


def fake_quant(x: jax.Array, bits: int, **kw) -> jax.Array:
    """Quantize-dequantize with STE — the float-domain QAT forward.

    Training uses this (gradients flow); serving uses the integer mantissas
    through the QMM engine.  Property tests assert both paths agree.
    """
    q = quantize_activation(x, bits, **kw)
    # Reconstruct in float WITHOUT dropping the gradient: redo the affine with
    # the STE'd q_float rather than the stop-gradient mantissa.
    qmax = float(2**bits - 1)
    q_float = ste_round(_ste_clip((x - q.offset) / q.scale, 0.0, qmax))
    return q_float * q.scale + q.offset


def fake_binarize_weight(w: jax.Array, per_channel_axis: int = -1) -> jax.Array:
    """Float-domain sign binarization with STE (for QAT train_step)."""
    del per_channel_axis  # scale per reduction dim (axis -2), as binarize_weight
    alpha = jnp.mean(jnp.abs(w), axis=-2, keepdims=True)
    sgn = w + jax.lax.stop_gradient(jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype) - w)
    return jax.lax.stop_gradient(alpha) * sgn
