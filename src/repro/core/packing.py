"""Bit-packing utilities: the storage substrate of BETA's QMM engine.

BETA (Fig. 4) packs several low-bit values into one hardware word so a PE
processes multiple multiplies per cycle.  On TPU the analogous win is HBM
footprint / bandwidth: n-bit mantissas are stored ``32/n`` to a ``uint32``
lane and unpacked on the fly inside the QMM kernel (HBM -> VMEM traffic for
binary weights drops 16x vs bf16).

Conventions
-----------
* Mantissas are **unsigned** n-bit integers in ``[0, 2**n)`` (the paper's
  ``x`` in ``alpha*x + gamma``).  Sign-binarized weights ``+-alpha`` are
  expressed as mantissa ``{0,1}`` with ``scale=2*alpha, offset=-alpha``.
* Packing is always along one axis (for QMM operands: the *reduction* dim),
  little-endian within the word: value ``i`` of a word occupies bits
  ``[i*n, (i+1)*n)``.
* Packed length is ``ceil(L / (32//n))``; the tail is zero-padded.  Zero
  mantissa padding is benign for the integer MM as long as row/col-sum
  corrections use the *logical* K (handled in flow_abstraction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "values_per_word",
    "packed_len",
    "pack_bits",
    "unpack_bits",
    "to_bitplanes",
    "from_bitplanes",
    "pack_bitplanes",
]

WORD_BITS = 32
_SUPPORTED_BITS = (1, 2, 4, 8, 16)


def values_per_word(bits: int) -> int:
    """Number of ``bits``-wide mantissas per uint32 word."""
    if bits not in _SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {_SUPPORTED_BITS}, got {bits}")
    return WORD_BITS // bits


def packed_len(length: int, bits: int) -> int:
    """Packed size along the packing axis."""
    vpw = values_per_word(bits)
    return -(-length // vpw)


def _move_axis_last(x: jax.Array, axis: int) -> jax.Array:
    return jnp.moveaxis(x, axis, -1)


@functools.partial(jax.jit, static_argnames=("bits", "axis"))
def pack_bits(x: jax.Array, bits: int, axis: int = -1) -> jax.Array:
    """Pack unsigned ``bits``-wide mantissas along ``axis`` into uint32 words.

    Args:
      x: integer array with values in ``[0, 2**bits)``.
      bits: mantissa width (1, 2, 4, 8 or 16).
      axis: axis to pack along.

    Returns:
      uint32 array; ``axis`` shrinks from ``L`` to ``ceil(L / (32//bits))``.
    """
    vpw = values_per_word(bits)
    x = _move_axis_last(x, axis).astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    length = x.shape[-1]
    pl_ = packed_len(length, bits)
    pad = pl_ * vpw - length
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x = x.reshape(x.shape[:-1] + (pl_, vpw))
    shifts = jnp.arange(vpw, dtype=jnp.uint32) * bits
    packed = jnp.bitwise_or.reduce(x << shifts, axis=-1).astype(jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


@functools.partial(jax.jit, static_argnames=("bits", "length", "axis", "dtype"))
def unpack_bits(
    packed: jax.Array,
    bits: int,
    length: int,
    axis: int = -1,
    dtype: jnp.dtype = jnp.int32,
) -> jax.Array:
    """Inverse of :func:`pack_bits`.

    Args:
      packed: uint32 packed array.
      bits: mantissa width.
      length: logical (unpadded) length along ``axis``.
      axis: packed axis.
      dtype: output dtype. Default int32 is safe for every ``bits``; pass
        int8 only when values are known to fit (e.g. bits <= 7, or re-centered
        signed mantissas) — that is the layout the MXU integer path wants.
    """
    vpw = values_per_word(bits)
    p = _move_axis_last(packed, axis)
    shifts = jnp.arange(vpw, dtype=jnp.uint32) * bits
    vals = (p[..., None] >> shifts) & jnp.uint32((1 << bits) - 1)
    vals = vals.reshape(p.shape[:-1] + (p.shape[-1] * vpw,))[..., :length]
    vals = vals.astype(dtype)
    return jnp.moveaxis(vals, -1, axis) if axis != -1 else vals


@functools.partial(jax.jit, static_argnames=("bits",))
def to_bitplanes(x: jax.Array, bits: int) -> jax.Array:
    """Decompose unsigned mantissas into ``bits`` binary planes.

    ``x = sum_i 2**i * plane[i]`` — the paper's bit-serial schedule (Fig. 4)
    traverses exactly these planes, one per cycle.

    Returns:
      uint8 array of shape ``(bits,) + x.shape`` with values in {0, 1}.
    """
    x = x.astype(jnp.uint32)
    shifts = jnp.arange(bits, dtype=jnp.uint32).reshape((bits,) + (1,) * x.ndim)
    return ((x[None] >> shifts) & 1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bits",))
def from_bitplanes(planes: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`to_bitplanes` (returns uint32)."""
    weights = (jnp.uint32(1) << jnp.arange(bits, dtype=jnp.uint32)).reshape(
        (bits,) + (1,) * (planes.ndim - 1)
    )
    return jnp.sum(planes.astype(jnp.uint32) * weights, axis=0, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits", "axis"))
def pack_bitplanes(x: jax.Array, bits: int, axis: int = -1) -> jax.Array:
    """Bit-plane decompose then 1-bit-pack each plane along ``axis``.

    Output shape: ``(bits,) + packed_shape`` — the operand layout consumed by
    the bit-serial act x act QMM kernel.
    """
    planes = to_bitplanes(x, bits)
    pack_axis = axis if axis < 0 else axis + 1
    return pack_bits(planes, 1, axis=pack_axis)


def pack_bits_np(x: np.ndarray, bits: int, axis: int = -1) -> np.ndarray:
    """NumPy twin of :func:`pack_bits` for checkpoint/serialization paths."""
    vpw = values_per_word(bits)
    x = np.moveaxis(np.asarray(x), axis, -1).astype(np.uint32) & np.uint32((1 << bits) - 1)
    length = x.shape[-1]
    pl_ = packed_len(length, bits)
    pad = pl_ * vpw - length
    if pad:
        x = np.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x = x.reshape(x.shape[:-1] + (pl_, vpw))
    shifts = (np.arange(vpw, dtype=np.uint32) * bits).astype(np.uint32)
    packed = np.bitwise_or.reduce(x << shifts, axis=-1).astype(np.uint32)
    return np.moveaxis(packed, -1, axis)
