"""Trace-time QMM site log — the hook the static verifier listens on.

Every serve-mode QMM site (dense ``qlinear`` projections, attention
act x act products) reports what it is about to execute: the site name,
the activation precision it quantized to, the mantissa dtype it produced,
and the backend dispatch resolved.  Recording is off by default and costs
one contextvar read per site; ``repro.analysis.verifier`` wraps its
abstract prefill/decode traces in :func:`recording` and then checks the
collected sites against the declared ``QuantConfig`` invariants (precision
per named site, mantissa-dtype contract, named-site coverage).

This lives in ``core`` (not ``analysis``) so model code never imports the
analysis package — the dependency points one way: analysis observes models.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, List, Optional

__all__ = ["recording", "record", "is_recording"]

_LOG: contextvars.ContextVar[Optional[List[Dict]]] = contextvars.ContextVar(
    "qmm_site_log", default=None
)


def is_recording() -> bool:
    return _LOG.get() is not None


@contextlib.contextmanager
def recording():
    """Collect site records emitted while the block runs (trace or execute).

    Yields the list the sites append to; nested recordings shadow the outer
    one (each verifier trace sees only its own sites).
    """
    token = _LOG.set([])
    try:
        yield _LOG.get()
    finally:
        _LOG.reset(token)


def record(**fields) -> None:
    """Append one site record if a recording is active (no-op otherwise).

    Canonical fields (see verifier.check_sites):
      kind: "qlinear" | "attn"
      site: dotted site name ("ffn.up", "attn.qk", ...); "" = unnamed
      bits: activation precision the site actually used
      cfg_bits: the precision QuantConfig declares for this site class
      mantissa_dtype: str dtype of the quantized mantissa fed to the engine
      backend: resolved backend string (qlinear sites only)
    """
    log = _LOG.get()
    if log is not None:
        log.append(dict(fields))
