"""Attention-scores bench: place every scores-family backend on the roofs.

The sibling of :mod:`repro.core.qmm_roofline` for the bitwise-attention
operator family (PR 10): one cell per (backend x attention shape), with the
analytical roofline columns next to measured wall-clock.

* the candidate set is ``backend_registry.backend_names(family="scores")``
  — a newly registered scores core shows up in the artifact with zero
  edits here;
* HBM traffic comes from the backend's registered ``traffic_model``
  (signature ``(m, k, n, act_bits, weight_bits)`` with the scores keying
  ``m = B*H*S``, ``k = dh``, ``n = T``, act=weight=1), falling back to
  :func:`repro.core.qmm_roofline.default_traffic`;
* useful work is ``2 * B*H*S * dh * T`` MAC-ops whatever the datapath —
  the binary AND-popcount core and the unpack->int8 MXU core do the same
  logical score matmul, they just pay different memory bills.

``BENCH_attn.json`` (schema ``attn-scores/v1``) is the perf-trajectory
artifact for the scores family: CI regenerates a smoke variant, validates
both against the schema, and validation requires every currently registered
scores backend to appear — adding a core without re-recording the artifact
fails the build on purpose.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend_registry, dispatch, packing
from repro.core.qmm_roofline import HBM_BW, PEAK_INT_OPS, default_traffic

__all__ = [
    "SCHEMA",
    "DEFAULT_SHAPES",
    "SMOKE_SHAPES",
    "make_planes",
    "cell_model",
    "measure_cell",
    "run_attn_bench",
    "validate_attn_bench",
    "save_attn_bench",
    "load_attn_bench",
    "format_table",
]

SCHEMA = "attn-scores/v1"

#: (B, H, G, S, T, dh): a prefill-shaped cell (square S x T), a GQA
#: decode-shaped cell (S=1 against a long cache), and a chunk-crossing T.
DEFAULT_SHAPES: Tuple[Tuple[int, int, int, int, int, int], ...] = (
    (1, 8, 8, 128, 128, 64),
    (2, 8, 2, 1, 256, 64),
    (1, 4, 2, 16, 384, 128),
)

SMOKE_SHAPES: Tuple[Tuple[int, int, int, int, int, int], ...] = (
    (1, 4, 2, 8, 16, 32),
)

_CELL_NUMERIC_KEYS = (
    "b",
    "h",
    "g",
    "s",
    "t",
    "dh",
    "flops",
    "bytes",
    "intensity",
    "t_compute_us",
    "t_memory_us",
    "roof_us",
    "measured_us",
)


def make_planes(
    b: int, heads: int, s: int, dh: int, *, seed: int = 0
) -> jax.Array:
    """Random packed {0,1} planes ``(B, heads, S, dw)`` for timing."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(b, heads, s, dh)).astype(np.uint32)
    return packing.pack_bits(jnp.asarray(bits), 1, axis=-1)


def cell_model(
    backend: str, b: int, h: int, g: int, s: int, t: int, dh: int
) -> Dict:
    """The analytical half of one cell: traffic, intensity, both roofs."""
    spec = backend_registry.get_backend(backend)
    traffic = spec.traffic_model or default_traffic
    m = b * h * s
    nbytes = float(traffic(m, dh, t, 1, 1))
    flops = 2.0 * m * dh * t
    t_compute = flops / PEAK_INT_OPS
    t_memory = nbytes / HBM_BW
    roof = max(t_compute, t_memory)
    return {
        "backend": backend,
        "b": int(b),
        "h": int(h),
        "g": int(g),
        "s": int(s),
        "t": int(t),
        "dh": int(dh),
        "flops": flops,
        "bytes": nbytes,
        "intensity": flops / nbytes if nbytes else 0.0,
        "t_compute_us": t_compute * 1e6,
        "t_memory_us": t_memory * 1e6,
        "roof_us": roof * 1e6,
        "bound": "compute" if t_compute >= t_memory else "memory",
    }


def measure_cell(
    backend: str,
    b: int,
    h: int,
    g: int,
    s: int,
    t: int,
    dh: int,
    *,
    warmup: int = 1,
    reps: int = 3,
) -> Dict:
    """One cell: the model columns plus measured wall-clock of the core."""
    cell = cell_model(backend, b, h, g, s, t, dh)
    spec = backend_registry.get_backend(backend)
    q_planes = make_planes(b, h, s, dh, seed=b * 31 + s)
    k_planes = make_planes(b, g, t, dh, seed=g * 37 + t)
    call = jax.jit(functools.partial(spec.run_scores, dh=dh))
    secs = dispatch._wallclock_timer(
        lambda: call(q_planes, k_planes), warmup=warmup, reps=reps
    )
    cell["measured_us"] = secs * 1e6
    return cell


def run_attn_bench(
    shapes: Sequence[Tuple[int, int, int, int, int, int]] = DEFAULT_SHAPES,
    backends: Optional[Iterable[str]] = None,
    *,
    warmup: int = 1,
    reps: int = 3,
) -> Dict:
    """Measure every (backend x shape) cell; returns the artifact doc."""
    names = (
        tuple(backends)
        if backends
        else backend_registry.backend_names(family="scores")
    )
    cells: List[Dict] = []
    for b, h, g, s, t, dh in shapes:
        for name in names:
            cells.append(measure_cell(name, b, h, g, s, t, dh,
                                      warmup=warmup, reps=reps))
    return {
        "schema": SCHEMA,
        "generated_unix": time.time(),
        "platform": jax.default_backend(),
        "hardware": {"hbm_bw": HBM_BW, "peak_int_ops": PEAK_INT_OPS},
        "backends": list(names),
        "cells": cells,
    }


def validate_attn_bench(doc: Dict) -> Dict:
    """Schema check; raises ValueError on any violation, returns ``doc``.

    Requires every currently registered scores-family backend to appear —
    an artifact recorded before a core was added must be re-recorded.
    """
    if doc.get("schema") != SCHEMA:
        raise ValueError(
            f"BENCH_attn schema mismatch: got {doc.get('schema')!r}, "
            f"want {SCHEMA!r}"
        )
    hw = doc.get("hardware")
    if not isinstance(hw, dict) or not all(
        isinstance(hw.get(k), (int, float)) for k in ("hbm_bw", "peak_int_ops")
    ):
        raise ValueError("BENCH_attn 'hardware' must carry numeric roofs")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        raise ValueError("BENCH_attn 'cells' must be a non-empty list")
    for i, c in enumerate(cells):
        if not isinstance(c.get("backend"), str):
            raise ValueError(f"BENCH_attn cell {i} missing 'backend'")
        if c.get("bound") not in ("compute", "memory"):
            raise ValueError(f"BENCH_attn cell {i} has invalid 'bound'")
        for key in _CELL_NUMERIC_KEYS:
            if not isinstance(c.get(key), (int, float)):
                raise ValueError(
                    f"BENCH_attn cell {i} key {key!r} must be numeric"
                )
    covered = {c["backend"] for c in cells}
    missing = set(backend_registry.backend_names(family="scores")) - covered
    if missing:
        raise ValueError(
            f"BENCH_attn is stale: registered scores backends "
            f"{sorted(missing)} have no cells — re-record with "
            "benchmarks/attn_micro.py --out"
        )
    return doc


def save_attn_bench(path: str, doc: Dict) -> None:
    validate_attn_bench(doc)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_attn_bench(path: str) -> Dict:
    with open(path) as f:
        return validate_attn_bench(json.load(f))


def format_table(doc: Dict) -> str:
    """Human-readable roofline placement, one line per cell."""
    lines = [
        f"# attn scores ({doc['platform']}; HBM "
        f"{doc['hardware']['hbm_bw']:.0f} B/s, int peak "
        f"{doc['hardware']['peak_int_ops']:.3g} op/s)",
        "backend   B  H  G  S    T    dh   bytes      AI       roof_us  "
        "bound    measured_us",
    ]
    for c in doc["cells"]:
        lines.append(
            f"{c['backend']:<9}{c['b']:<3}{c['h']:<3}{c['g']:<3}{c['s']:<5}"
            f"{c['t']:<5}{c['dh']:<5}"
            f"{c['bytes']:<11.3g}{c['intensity']:<9.1f}"
            f"{c['roof_us']:<9.3f}{c['bound']:<9}{c['measured_us']:.1f}"
        )
    return "\n".join(lines)
