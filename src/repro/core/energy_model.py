"""Analytical cycle / energy model of the BETA accelerator.

This container has no FPGA (and no TPU); the paper's evaluation artifacts —
Table I (resource breakdown), Table II (throughput / power / energy
efficiency) and Fig. 5 (precision <-> efficiency trade-off) — are reproduced
through a structural model of the accelerator:

* **Datapath** (§III-C): ``n_dpu`` DPUs, each unfolded over ``j_unfold``
  elements per cycle at ``freq_hz``.  Data-packing multiplies the per-PE rate
  by ``pack_factor`` (Fig. 4: 8/4/2/1 for A1/A2/A4/A8); act x act QMMs run
  bit-serially, dividing the rate by ``act_bits``.  The compressor-tree loop
  keeps the accumulation pipelined at 1 word/cycle (its entire point), so
  streaming MACs run at peak; fill/drain is one tree latency per dot-product
  row and is amortized.
* **Buffer traffic**: operands are pre-loaded to the compute buffer
  (§III-C); the load cost is ``operand_bits / load_bw_bits`` cycles and
  overlaps compute only partially (``load_overlap``).
* **Power**: static + per-mode dynamic power, calibrated once against the
  paper's three measured benchmark powers (7.18 / 7.95 / 8.20 W) — the same
  role SAIF-annotated switching activity plays in the paper's Vivado flow.

The model's free parameters are calibrated in ``benchmarks/table2_comparison``
against Table II and then *frozen*; Fig. 5's trend is a pure prediction of
the calibrated model.  All op counts flow through
``flow_abstraction.op_counts_*`` so the GOPS accounting matches the paper's
(ops counted on the original full-precision MM).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Literal, Tuple

from repro.core.precision import PrecisionMode, get_mode

__all__ = [
    "BetaHardware",
    "QMMShape",
    "qmm_cycles",
    "workload_cycles",
    "throughput_gops",
    "energy_efficiency",
    "bert_base_qmm_workload",
    "ZCU102_BETA",
]


@dataclasses.dataclass(frozen=True)
class BetaHardware:
    """Structural parameters of a BETA instance (paper §IV-B)."""

    n_dpu: int = 2
    j_unfold: int = 256
    freq_hz: float = 190e6
    # Compute-buffer load path (bits per cycle from off-chip / weight buffer).
    load_bw_bits: int = 2048
    # Fraction of load cycles hidden under compute (double-buffering).
    load_overlap: float = 0.8
    # Calibrated power model: P = p_static + p_dyn_per_tmacs * (TMAC/s).
    # Least-squares fit of Table II's three measured (power, rate) points —
    # they are collinear to ~2 mW, which corroborates the linear model.
    p_static_w: float = 0.6904
    p_dyn_w_per_tmacs: float = 10.459

    def peak_macs_per_cycle(self, mode: PrecisionMode, qmm_type: str) -> float:
        base = self.n_dpu * self.j_unfold * mode.pack_factor
        if qmm_type == "act_act":
            return base / mode.bitserial_cycles
        return base

    def peak_gops(self, mode: PrecisionMode, qmm_type: str = "act_weight") -> float:
        return 2.0 * self.peak_macs_per_cycle(mode, qmm_type) * self.freq_hz / 1e9


ZCU102_BETA = BetaHardware()


@dataclasses.dataclass(frozen=True)
class QMMShape:
    """One QMM in a workload: ``(M, K) @ (K, N)``, repeated ``count`` times."""

    m: int
    k: int
    n: int
    qmm_type: Literal["act_weight", "act_act"] = "act_weight"
    count: int = 1

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count


def qmm_cycles(shape: QMMShape, mode: PrecisionMode, hw: BetaHardware) -> float:
    """Cycles for one QMM on the engine.

    Compute: MACs *stream* at ``peak_macs_per_cycle``.  The compressor-tree
    loop carries two partial accumulations in carry-save form and finalizes
    through the carry-select adder asynchronously (§III-C, Fig. 3b) — this is
    exactly what lets consecutive dot products share an unfolded word, so
    there is no per-dot ceil-padding; only a pipeline fill of one tree depth
    per QMM remains.
    Load: activations enter the compute buffer at ``load_bw_bits``/cycle
    (binary weights are resident in the weight buffer); double-buffering
    hides ``load_overlap`` of it.
    """
    rate = hw.peak_macs_per_cycle(mode, shape.qmm_type)
    compute = shape.macs / rate
    fill = math.log2(hw.j_unfold) + 2  # compressor tree depth + CSA stage
    act_bits_in = shape.m * shape.k * mode.act_bits
    other_in = shape.k * shape.n * (
        mode.act_bits if shape.qmm_type == "act_act" else 0  # weights resident
    )
    load = (act_bits_in + other_in) * shape.count / hw.load_bw_bits
    exposed_load = load * (1.0 - hw.load_overlap)
    return compute + fill * shape.count + exposed_load


@dataclasses.dataclass(frozen=True)
class ModelOverhead:
    """Non-QMM work of one benchmark model (VPU epilogues + quantizers).

    The three Table-II benchmarks are all BERT-base at W1A1 yet differ in
    throughput (1241 / 1388 / 1436 GOPS) — the residual is each model's
    full-precision epilogue volume (BiT's elastic per-token quantizers do the
    most VPU work; BiBERT's bitwise Bi-Attention the least).  ``vpu_passes``
    is the calibrated number of (seq x d_model)-sized full-precision passes
    per layer executed on the 64-lane VPU.
    """

    name: str
    seq: int = 128
    d_model: int = 768
    n_layers: int = 12
    vpu_passes: float = 8.0
    vpu_lanes: int = 64

    def cycles(self) -> float:
        return self.n_layers * self.vpu_passes * self.seq * self.d_model / self.vpu_lanes


def workload_cycles(
    shapes: Iterable[QMMShape],
    mode: PrecisionMode,
    hw: BetaHardware,
    overhead: "ModelOverhead | None" = None,
) -> float:
    total = sum(qmm_cycles(s, mode, hw) for s in shapes)
    if overhead is not None:
        total += overhead.cycles()
    return total


def throughput_gops(
    shapes: Iterable[QMMShape],
    mode: PrecisionMode,
    hw: BetaHardware = ZCU102_BETA,
    overhead: "ModelOverhead | None" = None,
) -> Tuple[float, float]:
    """Returns (GOPS, latency_s).  Ops counted as 2*M*K*N per QMM — the
    original MM's op count, matching the paper's accounting."""
    shapes = list(shapes)
    cycles = workload_cycles(shapes, mode, hw, overhead)
    t = cycles / hw.freq_hz
    total_ops = 2.0 * sum(s.macs for s in shapes)
    return total_ops / t / 1e9, t


def power_w(
    shapes: Iterable[QMMShape],
    mode: PrecisionMode,
    hw: BetaHardware = ZCU102_BETA,
    overhead: "ModelOverhead | None" = None,
) -> float:
    gops, t = throughput_gops(list(shapes), mode, hw, overhead)
    tmacs = gops / 2.0 / 1e3  # tera-MACs/s
    return hw.p_static_w + hw.p_dyn_w_per_tmacs * tmacs


def energy_efficiency(
    shapes: Iterable[QMMShape],
    mode: PrecisionMode,
    hw: BetaHardware = ZCU102_BETA,
    overhead: "ModelOverhead | None" = None,
) -> float:
    """GOPS/W — the paper's headline metric."""
    shapes = list(shapes)
    gops, _ = throughput_gops(shapes, mode, hw, overhead)
    return gops / power_w(shapes, mode, hw, overhead)


def bert_base_qmm_workload(
    seq: int = 128,
    d_model: int = 768,
    n_heads: int = 12,
    d_ff: int = 3072,
    n_layers: int = 12,
) -> List[QMMShape]:
    """The QMM inventory of one BERT-base encoder pass (the paper's
    benchmarks BiT / BinaryBERT / BiBERT are all BERT-base on MNLI-m).

    act x weight: QKV+output projections and both FFN matmuls.
    act x act:    Q@K^T and P@V per head (the QMM type prior accelerators
    don't support — §II)."""
    d_head = d_model // n_heads
    return [
        QMMShape(seq, d_model, 3 * d_model, "act_weight", n_layers),  # QKV
        QMMShape(seq, d_model, d_model, "act_weight", n_layers),  # attn out
        QMMShape(seq, d_model, d_ff, "act_weight", n_layers),  # FFN up
        QMMShape(seq, d_ff, d_model, "act_weight", n_layers),  # FFN down
        QMMShape(seq, d_head, seq, "act_act", n_layers * n_heads),  # Q K^T
        QMMShape(seq, seq, d_head, "act_act", n_layers * n_heads),  # P V
    ]


# ---------------------------------------------------------------------------
# Calibration against Table II (run once in benchmarks/table2_comparison,
# frozen here; tests assert the frozen model reproduces the paper within 1%).
# ---------------------------------------------------------------------------

#: Paper Table II, BETA columns (W1A1 on ZCU102 @190 MHz, N=2, J=256).
PAPER_TABLE2 = {
    "BiT": {"gops": 1240.98, "power_w": 7.18, "gops_per_w": 172.41},
    "BinaryBERT": {"gops": 1387.59, "power_w": 7.95, "gops_per_w": 174.59},
    "BiBERT": {"gops": 1436.07, "power_w": 8.20, "gops_per_w": 175.23},
}

#: Paper Table II, baseline columns (same FPGA, traditional compute units).
PAPER_TABLE2_BASELINES = {
    "FP-32": {"gops": 13.51, "power_w": 11.64, "gops_per_w": 1.16},
    "FIX-16": {"gops": 72.09, "power_w": 3.91, "gops_per_w": 18.42},
}


def calibrate_vpu_passes(
    target_gops: float,
    shapes: Iterable[QMMShape],
    mode: PrecisionMode,
    hw: BetaHardware = ZCU102_BETA,
    seq: int = 128,
    d_model: int = 768,
    n_layers: int = 12,
    vpu_lanes: int = 64,
) -> float:
    """Solve (closed form) for the per-layer VPU pass count that makes the
    modeled throughput match a measured Table-II number."""
    shapes = list(shapes)
    total_ops = 2.0 * sum(s.macs for s in shapes)
    cycles_needed = total_ops / (target_gops * 1e9) * hw.freq_hz
    extra = cycles_needed - workload_cycles(shapes, mode, hw)
    return extra * vpu_lanes / (n_layers * seq * d_model)


def calibrate_power(points) -> Tuple[float, float]:
    """Least-squares (p_static, p_dyn_per_tmacs) from (tmacs, watts) pairs."""
    import numpy as np

    pts = list(points)
    a = np.array([[1.0, t] for t, _ in pts])
    b = np.array([w for _, w in pts])
    sol, *_ = np.linalg.lstsq(a, b, rcond=None)
    return float(sol[0]), float(sol[1])


#: Frozen calibration: per-benchmark VPU epilogue volume (see ModelOverhead).
BENCHMARK_OVERHEADS = {
    "BiT": ModelOverhead("BiT", vpu_passes=37.369),
    "BinaryBERT": ModelOverhead("BinaryBERT", vpu_passes=17.756),
    "BiBERT": ModelOverhead("BiBERT", vpu_passes=12.152),
}
