"""Computation-flow abstraction (the paper's §III-A, Fig. 2) — generalized.

Every binary-Transformer QMM operand is affine: ``alpha * X + gamma * 1``.
Instead of multiplying dequantized full-precision matrices (``N^3`` FP Ops),
the product is rewritten so that the cubic term is an **integer** matrix
multiply and every full-precision op is at most quadratic:

    (a1*X1 + g1*1)(a2*X2 + g2*1)
      = a1*a2 * (X1 @ X2)                # integer MM  (the QMM engine)
      + a1*g2 * rowsum(X1) . 1^T         # rank-1, integer rowsum
      + g1*a2 * 1 . colsum(X2)           # rank-1, integer colsum
      + g1*g2 * K * 1                    # constant

The paper's Fig. 2 is the special case ``g2 = 0`` (pure-coefficient weights):
``(aA + g*1) @ (bW) = (A@W)*(ab) + (1@W)*(gb)`` with ``ab``/``gb`` folded
offline.  This module implements the general form, which covers *both* QMM
types (activation x weight AND activation x activation) with offsets on both
operands — the capability the paper calls out as missing from prior
accelerators (VAQF et al.).

The integer MM itself is delegated to a pluggable backend (``int_matmul``):
the MXU int8 path, the Pallas fused unpack->dot kernel, or the popcount DPU
analogue — see ``repro.core.qmm`` / ``repro.kernels``.

Exactness: the rewrite is algebraically exact; property tests
(tests/test_flow_abstraction.py) assert equality with the dequantized FP
product to fp32 rounding.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quantization
from repro.core.quantization import QuantTensor

__all__ = [
    "default_int_matmul",
    "qmm_flow",
    "weight_corrections",
    "op_counts_naive",
    "op_counts_abstracted",
]

# int8 x int8 products over K accumulate in int32; chunk K when the worst-case
# accumulator |K * qmax1 * qmax2| would overflow.
_INT32_SAFE = 2**30


def matmul_dimension_numbers(x_ndim: int, y_ndim: int):
    """dot_general dims for ``(..., M, K) @ (K, N)`` or batched
    ``(..., M, K) @ (..., K, N)`` with shared leading batch dims."""
    if y_ndim == 2:
        return (((x_ndim - 1,), (0,)), ((), ()))
    if x_ndim != y_ndim:
        raise ValueError(f"rank mismatch for batched matmul: {x_ndim} vs {y_ndim}")
    batch = tuple(range(x_ndim - 2))
    return (((x_ndim - 1,), (y_ndim - 2,)), (batch, batch))


def default_int_matmul(
    x: jax.Array, y: jax.Array, x_bits: int, y_bits: int
) -> jax.Array:
    """Integer MM on the MXU: int8 operands, int32 accumulation.

    TPU's systolic array executes 8-bit integer MACs natively (at ~2x bf16
    rate) — this is the TPU-native realization of BETA's DPU datapath for
    mantissas up to 8 bits.  Callers pass mantissas already re-centered to a
    signed range (see ``repro.core.qmm``), so ``|x| <= 2**(x_bits-1)``.

    K is chunked when int32 accumulation could overflow (only reachable for
    8-bit x 8-bit beyond K ~ 64k); chunk partials are combined in fp32 —
    exact while |partial sums| < 2**24, which is the same accumulator
    contract real integer systolic arrays ship with.
    """
    k = x.shape[-1]
    max_prod = 2 ** (x_bits - 1 + y_bits - 1) if (x_bits > 1 or y_bits > 1) else 1
    max_prod = max(max_prod, 1)
    x8 = x.astype(jnp.int8)
    y8 = y.astype(jnp.int8)
    dimension_numbers = matmul_dimension_numbers(x.ndim, y.ndim)
    if max_prod * k <= _INT32_SAFE:
        return jax.lax.dot_general(
            x8, y8, dimension_numbers, preferred_element_type=jnp.int32
        )
    n_chunks = -(-max_prod * k // _INT32_SAFE)
    chunk = -(-k // n_chunks)
    total = None
    for s in range(0, k, chunk):
        xs = jax.lax.slice_in_dim(x8, s, min(s + chunk, k), axis=x.ndim - 1)
        ys = jax.lax.slice_in_dim(y8, s, min(s + chunk, k), axis=y.ndim - 2)
        part = jax.lax.dot_general(
            xs, ys, dimension_numbers, preferred_element_type=jnp.int32
        ).astype(jnp.float32)
        total = part if total is None else total + part
    return total


def _int_sum(x: jax.Array, axis: int) -> jax.Array:
    return jnp.sum(x.astype(jnp.int32), axis=axis, dtype=jnp.int32)


def weight_corrections(w: QuantTensor) -> jax.Array:
    """Pre-compute ``colsum(X2)`` for a weight operand (offline, like the
    paper folds ``alpha*beta`` / ``gamma*beta`` offline).

    Computed on the *re-centered* mantissa so it matches what
    :func:`qmm_flow` uses internally.
    """
    x2 = quantization.recenter(w).unpack().mantissa
    return _int_sum(x2, axis=-2)


def qmm_flow(
    x: QuantTensor,
    w: QuantTensor,
    *,
    int_matmul: Optional[Callable] = None,
    w_colsum: Optional[jax.Array] = None,
    out_dtype=jnp.float32,
    recenter: bool = True,
) -> jax.Array:
    """Affine x affine QMM via the computation-flow abstraction.

    Args:
      x: left operand, logical shape ``(..., M, K)``. ``scale``/``offset`` are
        scalar or broadcastable to ``(..., M, 1)`` (per-token).
      w: right operand, logical shape ``(K, N)`` (act x weight) or
        ``(..., K, N)`` (act x act). ``scale``/``offset`` scalar or
        broadcastable to ``(1, N)`` (per-out-channel).
      int_matmul: integer MM backend ``f(x_int, w_int, x_bits, w_bits)``.
      w_colsum: optional precomputed ``colsum`` of the right mantissa *as the
        integer core consumes it* — re-centered when ``recenter=True``
        (``weight_corrections``), raw otherwise.  For 1-bit weights the two
        coincide (re-centering is a no-op at bits <= 1).
      out_dtype: accumulation dtype of the full-precision epilogue.
      recenter: shift multi-bit mantissas to the signed range before the
        integer MM (exact — absorbed into the offsets).  Backends whose
        integer core consumes raw unsigned mantissas (popcount/bit-serial
        lanes; ``QMMBackend.needs_unsigned_mantissas``) pass ``False``: the
        affine identity holds for either representation, so the epilogue is
        shared verbatim.

    Returns:
      The full-precision product, shape ``(..., M, N)``.
    """
    int_matmul = int_matmul or default_int_matmul
    if recenter:
        # Re-center multi-bit mantissas to the signed range so the int8 MXU
        # path applies at every precision (exact — absorbed into the offsets).
        x = quantization.recenter(x)
        w = quantization.recenter(w)
    x1 = x.unpack().mantissa
    x2 = w.unpack().mantissa
    k = x1.shape[-1]
    if x2.shape[-2] != k:
        raise ValueError(f"reduction mismatch: {x1.shape} @ {x2.shape}")

    a1 = jnp.asarray(x.scale, out_dtype)
    g1 = jnp.asarray(x.offset, out_dtype)
    a2 = jnp.asarray(w.scale, out_dtype)
    g2 = jnp.asarray(w.offset, out_dtype)

    # --- cubic term: pure integer MM on the engine ---
    xy = int_matmul(x1, x2, x.bits, w.bits).astype(out_dtype)

    # --- quadratic/rank-1 corrections (the VPU's job in BETA) ---
    out = xy * (a1 * a2)
    # a1*g2 * rowsum(X1): (..., M, 1) broadcast over N.
    row = _int_sum(x1, axis=-1)[..., None].astype(out_dtype)
    out = out + (a1 * g2) * row
    # g1*a2 * colsum(X2): (..., 1, N) broadcast over M.
    col = (w_colsum if w_colsum is not None else _int_sum(x2, axis=-2))
    col = col[..., None, :].astype(out_dtype)
    out = out + (g1 * a2) * col
    # g1*g2*K constant.
    out = out + g1 * g2 * jnp.asarray(k, out_dtype)
    return out


def qmm_dequant_reference(x: QuantTensor, w: QuantTensor, out_dtype=jnp.float32):
    """The *naive* flow the paper replaces: dequantize both operands to full
    precision and multiply (N^3 FP Ops).  Kept as the correctness oracle and
    as the FP baseline for Table II reproduction."""
    xd = x.dequantize(out_dtype)
    wd = w.dequantize(out_dtype)
    dn = matmul_dimension_numbers(xd.ndim, wd.ndim)
    return jax.lax.dot_general(xd, wd, dn, preferred_element_type=out_dtype)


# ---------------------------------------------------------------------------
# Op counting (Fig. 2's complexity accounting, used by the energy model and
# the Table II benchmark).
# ---------------------------------------------------------------------------

def op_counts_naive(m: int, k: int, n: int) -> dict:
    """Full-precision MM of dequantized operands: M*N dots of length K."""
    return {"fp_ops": 2 * m * k * n, "int_ops": 0}


def op_counts_abstracted(m: int, k: int, n: int, *, weight_static: bool = True) -> dict:
    """Abstracted flow: integer MM + quadratic FP epilogue.

    Matches Fig. 2's ``2N^3 Iop + (3N^2 + 2) Op`` for m=k=n, weight_static
    (colsum offline, coefficient products offline).
    """
    int_ops = 2 * m * k * n  # the integer MM (MACs counted as 2 ops)
    int_ops += m * k  # rowsum(X1)
    if not weight_static:
        int_ops += k * n  # colsum(X2) when the right operand is an activation
    fp_ops = m * n  # scale by a1*a2
    fp_ops += m * n  # add rank-1 row correction (broadcast add)
    fp_ops += m * n  # add rank-1 col correction + constant (fused broadcast)
    fp_ops += 2  # offline coefficient products a1*a2, g1*a2 (paper's "+2")
    return {"fp_ops": fp_ops, "int_ops": int_ops}
