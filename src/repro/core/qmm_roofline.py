"""QMM backend roofline: place every registered backend against the roofs.

The serving roofline (``benchmarks/roofline.py``) charges whole programs from
dry-run HLO cost analysis; this module does the same accounting for a single
QMM problem, per *backend*, using the registry as the source of truth:

* the candidate set is ``backend_registry.backend_names(family="qmm")`` —
  a newly registered QMM backend shows up in the artifact with zero edits
  here (scores-family backends have their own artifact, ``BENCH_attn.json``);
* each backend's HBM traffic comes from its registered ``traffic_model``
  capability (falling back to :func:`default_traffic`, the packed-operand
  floor, when a backend declares none);
* the useful work is always ``2*M*K*N`` MAC-ops regardless of datapath —
  that is the point of a roofline: the fused kernel and the MXU path do the
  same logical matmul, they just pay different memory bills for it.

Roofs (TPU v5e, per chip): 819 GB/s HBM; the int8 MXU peak is twice the
197 TFLOP/s bf16 figure.  Measured wall-clock comes from the same
best-of-``reps`` timer the autotuner uses, over the same synthetic problems
(``dispatch.make_problem``) — so a cell's ``measured_us`` is directly
comparable to the autotune cache's ``timings_us``.  On a CPU host the
measured numbers are interpret-mode proxies and only the *model* columns
(``t_memory_us`` / ``t_compute_us`` / ``bound``) transfer to the TPU; the
artifact records the platform so readers can tell which regime they hold.

``BENCH_qmm.json`` (schema ``qmm-roofline/v1``) is the perf-trajectory
artifact for the QMM engine, the sibling of ``BENCH_serve.json``: CI
regenerates a smoke variant and validates both against the schema, and
validation requires every *currently registered* backend to appear — adding
a backend without re-recording the artifact fails the build on purpose.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from repro.core import backend_registry, dispatch, packing

__all__ = [
    "SCHEMA",
    "HBM_BW",
    "PEAK_INT_OPS",
    "DEFAULT_SHAPES",
    "DEFAULT_PRECISIONS",
    "SMOKE_SHAPES",
    "SMOKE_PRECISIONS",
    "default_traffic",
    "cell_model",
    "measure_cell",
    "run_qmm_roofline",
    "validate_qmm_bench",
    "save_qmm_bench",
    "load_qmm_bench",
    "format_table",
]

SCHEMA = "qmm-roofline/v1"

#: TPU v5e per-chip roofs (matches benchmarks/roofline.py HBM figure).
HBM_BW = 819e9
PEAK_INT_OPS = 394e12  # int8 MXU: 2x the 197 TFLOP/s bf16 peak

#: (M, K, N): a prefill-shaped tile and a decode-shaped one, both small
#: enough that the interpret-mode Pallas paths stay measurable off-TPU.
DEFAULT_SHAPES: Tuple[Tuple[int, int, int], ...] = ((64, 512, 512), (8, 512, 512))
#: (act_bits, weight_bits): the paper's W1A1 / W1A8 modes plus the A8xA8
#: attention case.
DEFAULT_PRECISIONS: Tuple[Tuple[int, int], ...] = ((1, 1), (8, 1), (8, 8))

SMOKE_SHAPES: Tuple[Tuple[int, int, int], ...] = ((8, 128, 128),)
SMOKE_PRECISIONS: Tuple[Tuple[int, int], ...] = ((1, 1), (8, 1), (8, 8))

_CELL_NUMERIC_KEYS = (
    "m",
    "k",
    "n",
    "act_bits",
    "weight_bits",
    "flops",
    "bytes",
    "intensity",
    "t_compute_us",
    "t_memory_us",
    "roof_us",
    "measured_us",
)


def default_traffic(m: int, k: int, n: int, act_bits: int, weight_bits: int) -> int:
    """Packed-operand HBM floor for a backend with no declared traffic model.

    Both operands as 1-bit planes (the minimum any bit-serial datapath must
    read), the fp32 result out, plus the rank-1 correction vectors.
    """
    kw_bytes = 4 * packing.packed_len(k, 1)
    return (
        act_bits * m * kw_bytes
        + weight_bits * kw_bytes * n
        + 4 * m * n
        + 8 * (m + n)
    )


def cell_model(
    backend: str, m: int, k: int, n: int, act_bits: int, weight_bits: int
) -> Dict:
    """The analytical half of one cell: traffic, intensity, both roofs."""
    spec = backend_registry.get_backend(backend)
    traffic = spec.traffic_model or default_traffic
    nbytes = float(traffic(m, k, n, act_bits, weight_bits))
    flops = 2.0 * m * k * n
    t_compute = flops / PEAK_INT_OPS
    t_memory = nbytes / HBM_BW
    roof = max(t_compute, t_memory)
    return {
        "backend": backend,
        "m": int(m),
        "k": int(k),
        "n": int(n),
        "act_bits": int(act_bits),
        "weight_bits": int(weight_bits),
        "flops": flops,
        "bytes": nbytes,
        "intensity": flops / nbytes if nbytes else 0.0,
        "t_compute_us": t_compute * 1e6,
        "t_memory_us": t_memory * 1e6,
        "roof_us": roof * 1e6,
        "bound": "compute" if t_compute >= t_memory else "memory",
    }


def measure_cell(
    backend: str,
    m: int,
    k: int,
    n: int,
    act_bits: int,
    weight_bits: int,
    *,
    warmup: int = 1,
    reps: int = 3,
) -> Dict:
    """One roofline cell: the model columns plus measured wall-clock.

    Times ``qmm(backend=...)`` on the autotuner's synthetic problem for the
    same key, so measured numbers line up with autotune-cache timings.
    """
    import functools

    from repro.core import qmm as QE

    cell = cell_model(backend, m, k, n, act_bits, weight_bits)
    key = dispatch.TuneKey(m, k, n, act_bits, weight_bits, (backend,))
    xq, wq, colsum = dispatch.make_problem(key)
    call = jax.jit(functools.partial(QE.qmm, backend=backend, w_colsum=colsum))
    secs = dispatch._wallclock_timer(
        lambda: call(xq, wq), warmup=warmup, reps=reps
    )
    cell["measured_us"] = secs * 1e6
    return cell


def run_qmm_roofline(
    shapes: Sequence[Tuple[int, int, int]] = DEFAULT_SHAPES,
    precisions: Sequence[Tuple[int, int]] = DEFAULT_PRECISIONS,
    backends: Optional[Iterable[str]] = None,
    *,
    warmup: int = 1,
    reps: int = 3,
) -> Dict:
    """Measure every (backend x shape x precision) cell; returns the doc."""
    names = (
        tuple(backends)
        if backends
        else backend_registry.backend_names(family="qmm")
    )
    cells: List[Dict] = []
    for m, k, n in shapes:
        for ab, wb in precisions:
            for b in names:
                cells.append(
                    measure_cell(b, m, k, n, ab, wb, warmup=warmup, reps=reps)
                )
    return {
        "schema": SCHEMA,
        "generated_unix": time.time(),
        "platform": jax.default_backend(),
        "hardware": {"hbm_bw": HBM_BW, "peak_int_ops": PEAK_INT_OPS},
        "backends": list(names),
        "cells": cells,
    }


def validate_qmm_bench(doc: Dict) -> Dict:
    """Schema check; raises ValueError on any violation, returns ``doc``.

    Requires every currently *registered* backend to appear in the cells —
    an artifact recorded before a backend was added must be re-recorded.
    """
    if doc.get("schema") != SCHEMA:
        raise ValueError(
            f"BENCH_qmm schema mismatch: got {doc.get('schema')!r}, want {SCHEMA!r}"
        )
    hw = doc.get("hardware")
    if not isinstance(hw, dict) or not all(
        isinstance(hw.get(k), (int, float)) for k in ("hbm_bw", "peak_int_ops")
    ):
        raise ValueError("BENCH_qmm 'hardware' must carry numeric roofs")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        raise ValueError("BENCH_qmm 'cells' must be a non-empty list")
    for i, c in enumerate(cells):
        if not isinstance(c.get("backend"), str):
            raise ValueError(f"BENCH_qmm cell {i} missing 'backend'")
        if c.get("bound") not in ("compute", "memory"):
            raise ValueError(f"BENCH_qmm cell {i} has invalid 'bound'")
        for key in _CELL_NUMERIC_KEYS:
            if not isinstance(c.get(key), (int, float)):
                raise ValueError(f"BENCH_qmm cell {i} key {key!r} must be numeric")
    covered = {c["backend"] for c in cells}
    missing = set(backend_registry.backend_names(family="qmm")) - covered
    if missing:
        raise ValueError(
            f"BENCH_qmm is stale: registered backends {sorted(missing)} have no "
            "roofline cells — re-record with benchmarks/roofline.py --qmm-out"
        )
    return doc


def save_qmm_bench(path: str, doc: Dict) -> None:
    validate_qmm_bench(doc)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_qmm_bench(path: str) -> Dict:
    with open(path) as f:
        return validate_qmm_bench(json.load(f))


def format_table(doc: Dict) -> str:
    """Human-readable roofline placement, one line per cell."""
    lines = [
        f"# qmm roofline ({doc['platform']}; HBM {doc['hardware']['hbm_bw']:.0f} B/s,"
        f" int peak {doc['hardware']['peak_int_ops']:.3g} op/s)",
        "backend   M     K     N    A/W   bytes      AI       roof_us  bound    measured_us",
    ]
    for c in doc["cells"]:
        lines.append(
            f"{c['backend']:<9}{c['m']:<6}{c['k']:<6}{c['n']:<5}"
            f"{c['act_bits']}/{c['weight_bits']:<4}"
            f"{c['bytes']:<11.3g}{c['intensity']:<9.1f}"
            f"{c['roof_us']:<9.3f}{c['bound']:<9}{c['measured_us']:.1f}"
        )
    return "\n".join(lines)
