"""Precision modes of the configurable QMM engine (paper Fig. 4).

BETA's PE sequence serves every ``W1 x Aa`` combination plus multi-bit
activation x activation by combining data-packing (several low-bit multiplies
per PE word per cycle) and bit-serial traversal (one activation bit-plane per
cycle).  This registry is the software mirror: each mode fixes the operand
bit-widths, the packing factor the engine claims, and the bit-serial cycle
count — consumed by the QMM dispatcher and the energy/cycle model.

``Wb_w Ab_a`` notation follows BiT [11].
"""

from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["PrecisionMode", "MODES", "get_mode", "W1A1", "W1A2", "W1A4", "W1A8"]


@dataclasses.dataclass(frozen=True)
class PrecisionMode:
    """One operating point of the configurable QMM engine.

    Attributes:
      name: e.g. "W1A4".
      weight_bits: weight mantissa width (1 for every binary-Transformer mode).
      act_bits: activation mantissa width.
      pack_factor: multiplies per PE per cycle for act x weight (Fig. 4:
        W1A8 -> 1, W1A4 -> 2, W1A2 -> 4, W1A1 -> 8; the PE output register is
        8 bits wide and holds ``pack_factor`` packed partial products).
      bitserial_cycles: extra serial factor for act x act QMM — one operand is
        traversed bit-plane by bit-plane, so an ``Aa x Aa`` product takes
        ``a`` passes of the binary engine.
    """

    name: str
    weight_bits: int
    act_bits: int
    pack_factor: int
    bitserial_cycles: int

    @property
    def key(self) -> str:
        return self.name


def _mk(act_bits: int) -> PrecisionMode:
    return PrecisionMode(
        name=f"W1A{act_bits}",
        weight_bits=1,
        act_bits=act_bits,
        pack_factor=8 // act_bits,
        bitserial_cycles=act_bits,
    )


W1A1 = _mk(1)
W1A2 = _mk(2)
W1A4 = _mk(4)
W1A8 = _mk(8)

MODES: Dict[str, PrecisionMode] = {m.name: m for m in (W1A1, W1A2, W1A4, W1A8)}


def get_mode(name: str) -> PrecisionMode:
    try:
        return MODES[name]
    except KeyError:
        raise KeyError(f"unknown precision mode {name!r}; have {sorted(MODES)}") from None
