"""Pluggable QMM backend registry — the engine's extension point.

BETA's QMM engine (§III-C) is a *configurable* datapath: one engine serving
every precision mode.  The software analogue used to be a hardcoded
``BACKENDS`` tuple plus an ``if backend == ...`` chain in ``core.qmm`` — every
new backend had to be hand-threaded through the dispatcher, the config
validator, and the analysis sweep.  This module replaces all of that with a
registry: a backend is one :class:`QMMBackend` spec (a run callable plus
capability flags), registered by name, and every consumer — ``qmm(backend=)``
validation, ``dispatch.candidate_backends``, ``QuantConfig`` error messages,
the verifier sweep, the roofline bench — enumerates the registry instead of
literals.  Registering a new backend requires zero dispatcher edits.

Capability flags:

* ``precisions``  — the ``(act_bits, weight_bits)`` pairs the backend can
  run, or ``None`` for "all".  ``weight_bits`` follows the qmm convention:
  the *right* operand's bits (so act x act shows up as e.g. ``(8, 8)``).
* ``rank2_only``  — the backend only accepts rank-2 operands (Pallas
  kernels; callers flatten leading batch dims).
* ``needs_unsigned_mantissas`` — the integer core consumes raw unsigned
  mantissas (popcount lanes); the epilogue must skip re-centering.
* ``probe``       — optional ``f(m, k, n) -> bool`` availability check for
  one problem size on this host (e.g. interpret-mode kernels are only
  offered on problems small enough to time cheaply).
* ``traffic_model`` — optional ``f(m, k, n, act_bits, weight_bits) -> int``
  returning the backend's modeled HBM bytes for one QMM; the roofline bench
  (``core.qmm_roofline``) uses it to place the backend against the
  memory-bandwidth roof.  Defaults to the fully-packed traffic model.
* ``families``    — the operator families the backend serves.  ``"qmm"`` is
  the rank-2 quantized matmul family (the ``run`` contract); ``"scores"``
  is the rank-4 attention-scores family (the ``run_scores`` contract:
  packed uint32 Q/K planes in, int32 AND-popcount scores out, W1A1 only).
  One backend may serve both (``mxu`` does); a scores-only backend is
  rejected by ``qmm`` and never enumerated for qmm-family autotuning.

Built-in backends live next to their implementations and self-register on
import: ``repro.core.qmm`` registers ``mxu`` and ``popcount``;
``repro.kernels.ops`` registers ``pallas`` and ``fused``.  Enumeration
functions trigger those imports lazily so the registration order (and hence
candidate order) is deterministic regardless of which module is imported
first.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, FrozenSet, Optional, Tuple

__all__ = [
    "QMMBackend",
    "register",
    "register_backend",
    "unregister",
    "get_backend",
    "backend_names",
    "backend_specs",
    "candidate_names",
]


@dataclasses.dataclass(frozen=True)
class QMMBackend:
    """One QMM integer-core backend: its entry point plus capabilities.

    ``run`` has the uniform signature
    ``run(x: QuantTensor, w: QuantTensor, *, w_colsum, out_dtype) -> Array``
    — exactly what ``qmm`` forwards after resolving ``backend="auto"``.
    """

    name: str
    run: Callable
    description: str = ""
    #: Supported (act_bits, weight_bits) pairs; None means "every precision".
    precisions: Optional[FrozenSet[Tuple[int, int]]] = None
    #: Only rank-2 operands (callers flatten batch dims first).
    rank2_only: bool = False
    #: Integer core consumes raw unsigned mantissas (no re-centering).
    needs_unsigned_mantissas: bool = False
    #: Optional per-problem availability check on this host.
    probe: Optional[Callable[[int, int, int], bool]] = None
    #: Optional modeled HBM bytes f(m, k, n, act_bits, weight_bits).
    traffic_model: Optional[Callable[[int, int, int, int, int], int]] = None
    #: Operator families served: "qmm" (rank-2 matmul via ``run``) and/or
    #: "scores" (rank-4 attention scores via ``run_scores``).
    families: FrozenSet[str] = frozenset({"qmm"})
    #: Attention-scores entry point, required for the "scores" family:
    #: ``run_scores(q_planes: u32 (B,H,S,dw), k_planes: u32 (B,G,T,dw), *,
    #: dh: int) -> int32 (B,H,S,T)`` — AND-popcount counts in the unsigned
    #: {0,1} plane domain; the caller applies the affine epilogue.
    run_scores: Optional[Callable] = None

    def supports_precision(self, act_bits: int, weight_bits: int) -> bool:
        if self.precisions is None:
            return True
        return (int(act_bits), int(weight_bits)) in self.precisions

    def eligible(
        self,
        m: int,
        k: int,
        n: int,
        act_bits: int,
        weight_bits: int,
        *,
        rank2: bool = True,
        family: str = "qmm",
    ) -> bool:
        """Can this backend serve this problem on this host?"""
        if family not in self.families:
            return False
        if family == "scores" and self.run_scores is None:
            return False
        if family == "qmm" and self.rank2_only and not rank2:
            return False
        if not self.supports_precision(act_bits, weight_bits):
            return False
        if self.probe is not None and not self.probe(int(m), int(k), int(n)):
            return False
        return True


_REGISTRY: Dict[str, QMMBackend] = {}

# Modules whose import registers the built-in backends, in candidate order.
_BUILTIN_MODULES = ("repro.core.qmm", "repro.kernels.ops")
_builtins_loaded = False


def _ensure_builtins() -> None:
    """Import the built-in backend modules once (idempotent, cycle-safe:
    neither module calls back into the enumeration functions at import)."""
    global _builtins_loaded
    if _builtins_loaded:
        return
    _builtins_loaded = True
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def register(spec: QMMBackend) -> QMMBackend:
    """Add ``spec`` to the registry.  Duplicate names are an error — a
    backend's name is its identity in autotune caches and configs."""
    if spec.name in _REGISTRY:
        raise ValueError(f"backend {spec.name!r} is already registered")
    if not spec.name or spec.name == "auto":
        raise ValueError(f"invalid backend name {spec.name!r}")
    _REGISTRY[spec.name] = spec
    return spec


def register_backend(name: str, **caps):
    """Decorator form: ``@register_backend("fused", rank2_only=True, ...)``
    over the run callable.  Returns the callable unchanged so the module can
    still export it directly."""

    def deco(fn: Callable) -> Callable:
        register(QMMBackend(name=name, run=fn, **caps))
        return fn

    return deco


def unregister(name: str) -> None:
    """Remove a backend (test isolation; no-op if absent)."""
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> QMMBackend:
    """Look up a backend spec by name; ValueError lists the known names."""
    _ensure_builtins()
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(backend_names())}"
        )
    return spec


def backend_names(family: Optional[str] = None) -> Tuple[str, ...]:
    """Every registered backend name, in registration order.  With
    ``family``, only backends serving that operator family."""
    _ensure_builtins()
    if family is None:
        return tuple(_REGISTRY)
    return tuple(
        name for name, spec in _REGISTRY.items() if family in spec.families
    )


def backend_specs() -> Tuple[QMMBackend, ...]:
    _ensure_builtins()
    return tuple(_REGISTRY.values())


def candidate_names(
    m: int,
    k: int,
    n: int,
    act_bits: int,
    weight_bits: int,
    *,
    rank2: bool = True,
    family: str = "qmm",
) -> Tuple[str, ...]:
    """Names of every backend eligible for this problem on this host —
    the availability component of the autotune cache key."""
    _ensure_builtins()
    return tuple(
        spec.name
        for spec in _REGISTRY.values()
        if spec.eligible(m, k, n, act_bits, weight_bits, rank2=rank2, family=family)
    )
