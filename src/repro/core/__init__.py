"""Core of the BETA reproduction: the paper's primary contribution in JAX.

- ``packing``           bit-packed storage (uint32 lanes, bit-planes)
- ``quantization``      QuantTensor + BiT-style quantizers (+ STE for QAT)
- ``flow_abstraction``  the computation-flow rewrite (§III-A, Fig. 2)
- ``precision``         the configurable engine's W1A{1,2,4,8} mode registry
- ``qmm``               the QMM engine dispatcher (MXU / popcount / Pallas)
- ``dispatch``          measured backend autotuning behind qmm(backend="auto")
- ``energy_model``      BETA cycle & energy model (Tables I/II, Fig. 5)
"""

from repro.core import dispatch, flow_abstraction, packing, precision, qmm, quantization

__all__ = [
    "dispatch",
    "flow_abstraction",
    "packing",
    "precision",
    "qmm",
    "quantization",
]
