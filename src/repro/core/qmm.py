"""The QMM engine: precision-configurable quantized matmul dispatch.

This is the software counterpart of BETA's QMM engine (§III-C): one entry
point that serves both QMM types (activation x weight, activation x
activation) at every supported activation precision, on top of the
computation-flow abstraction (``flow_abstraction.qmm_flow``).

Backends for the integer MM core:

* ``"mxu"``      — int8 ``lax.dot_general`` (int32 accum). TPU-native: the
                   systolic array does 8-bit integer MACs at ~2x bf16 rate.
                   Default for model forward passes and the dry-run path.
* ``"popcount"`` — AND+popcount over bit-packed uint32 lanes — the faithful
                   analogue of BETA's XNOR-popcount DPU. (With the unified
                   unsigned-mantissa form, +-1 XNOR-popcount becomes {0,1}
                   AND-popcount; the affine epilogue absorbs the difference,
                   which is why one datapath serves both operand kinds.)
                   Multi-bit operands run bit-serially over planes (Fig. 4).
* ``"pallas"``   — the Pallas TPU kernels in ``repro.kernels`` (fused
                   unpack -> MXU dot with VMEM tiling); falls back to
                   interpret mode off-TPU.

* ``"fused"``    — one Pallas kernel running the whole bit-serial schedule
                   (pack-plane AND-popcount, cross-plane accumulate, affine
                   epilogue) without touching HBM between stages — the
                   closest software analogue of BETA's fused datapath.

Backends are *registered*, not hardcoded: each one is a
``repro.core.backend_registry.QMMBackend`` spec (run callable + capability
flags), and ``qmm(backend=...)`` resolves names through the registry.  This
module registers ``mxu`` and ``popcount``; ``repro.kernels.ops`` registers
``pallas`` and ``fused``.  Adding a backend elsewhere requires no edits here.

All backends return results that agree exactly (integer math) and match the
dequantized FP reference to fp32 rounding — property-tested.  Because the
backends agree numerically, ``backend="auto"`` is free to pick whichever is
fastest: it consults the measured autotune cache in ``repro.core.dispatch``
(keyed on shape, precision, and backend availability) instead of a
hardcoded default.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend_registry, flow_abstraction, packing
from repro.core.precision import PrecisionMode
from repro.core.quantization import QuantTensor

__all__ = ["qmm", "and_popcount_matmul", "popcount_int_matmul"]

# Columns of the right operand processed per popcount sweep; bounds the
# broadcast intermediate to n_chunk * M * Kw words (VMEM-sized blocks in the
# Pallas kernel play the same role).
_POPCOUNT_N_CHUNK = 256


def and_popcount_matmul(a_packed: jax.Array, b_packed: jax.Array) -> jax.Array:
    """Binary integer MM over bit-packed operands.

    ``out[m, n] = sum_w popcount(a[m, w] & b[w, n])`` — BETA's DPU datapath
    expressed in lane-parallel jnp (the Pallas kernel tiles exactly this).

    Args:
      a_packed: uint32 ``(..., M, Kw)`` — K packed along the last axis.
      b_packed: uint32 ``(..., Kw, N)`` — K packed along the second-to-last.

    Returns:
      int32 ``(..., M, N)``.
    """
    m = a_packed.shape[-2]
    n = b_packed.shape[-1]
    out_chunks = []
    for s in range(0, n, _POPCOUNT_N_CHUNK):
        b_blk = jax.lax.slice_in_dim(b_packed, s, min(s + _POPCOUNT_N_CHUNK, n), axis=-1)
        # (..., M, 1, Kw) & (..., 1, Nc, Kw) -> popcount -> sum over Kw.
        joint = a_packed[..., :, None, :] & jnp.swapaxes(b_blk, -1, -2)[..., None, :, :]
        out_chunks.append(
            jnp.sum(jax.lax.population_count(joint).astype(jnp.int32), axis=-1)
        )
    return jnp.concatenate(out_chunks, axis=-1) if len(out_chunks) > 1 else out_chunks[0]


def popcount_int_matmul(
    x: jax.Array, y: jax.Array, x_bits: int, y_bits: int
) -> jax.Array:
    """``int_matmul`` backend built from AND-popcount + bit-serial planes.

    Accepts *unpacked* unsigned mantissas (the ``qmm_flow`` contract), packs
    bit-planes, and accumulates ``sum_ij 2^(i+j) popcount-MM(X_i, Y_j)`` —
    the paper's bit-serial schedule.  Exact for unsigned mantissas; callers
    must not pre-recenter (use ``qmm(..., backend='popcount')`` which skips
    re-centering).
    """
    a_planes = packing.pack_bitplanes(x.astype(jnp.uint32), x_bits, axis=-1)
    b_planes = packing.pack_bitplanes(y.astype(jnp.uint32), y_bits, axis=-2)
    total = None
    for i in range(x_bits):
        for j in range(y_bits):
            part = and_popcount_matmul(a_planes[i], b_planes[j]) << (i + j)
            total = part if total is None else total + part
    return total


def qmm(
    x: QuantTensor,
    w: QuantTensor,
    *,
    backend: str = "auto",
    mode: Optional[PrecisionMode] = None,
    w_colsum: Optional[jax.Array] = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Quantized matmul through the flow abstraction, backend-dispatched.

    Args:
      x: left operand ``(..., M, K)`` QuantTensor.
      w: right operand ``(K, N)`` or ``(..., K, N)`` QuantTensor.
      backend: "auto" or any name registered in ``core.backend_registry``
        ("mxu", "popcount", "pallas", "fused", ...).
      mode: optional PrecisionMode for engine-config asserts.
      w_colsum: precomputed integer colsum of the (re-centered) right mantissa.
      out_dtype: epilogue dtype.
    """
    if mode is not None:
        if (x.bits, w.bits) not in {
            (mode.act_bits, mode.weight_bits),
            (mode.act_bits, mode.act_bits),
        }:
            raise ValueError(
                f"operands W{w.bits}A{x.bits} do not match engine mode {mode.name}"
            )
    from repro.core import dispatch

    if backend == "auto":
        # Measured dispatch (core.dispatch): look up — or time-and-record —
        # the winning backend for this (M, K, N, precisions, phase) key.
        # Under jax.jit this runs once at trace time (shapes are static).
        x_l, w_l = x.logical_shape, w.logical_shape
        m = 1
        for d in x_l[:-1]:
            m *= int(d)
        rank2 = len(x_l) == 2 and len(w_l) == 2  # pallas needs rank-2
        backend = dispatch.choose_backend(
            m, int(x_l[-1]), int(w_l[-1]), x.bits, w.bits, rank2=rank2
        )
    else:
        # Demotions override explicit names too: a backend the serving
        # engine has pinned away from must not come back via a config
        # literal or per-layer override while the pin is active.
        backend = dispatch.resolve_backend(backend)
    spec = backend_registry.get_backend(backend)  # ValueError on unknown name
    if "qmm" not in spec.families:
        raise ValueError(
            f"backend {backend!r} serves families {sorted(spec.families)}, "
            "not the qmm family; scores-only backends go through "
            "kernels.ops.binary_attn_scores"
        )
    return spec.run(x, w, w_colsum=w_colsum, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Built-in jnp backends (the Pallas-backed ones register in repro.kernels.ops)
# ---------------------------------------------------------------------------


def _mxu_traffic(m, k, n, act_bits, weight_bits) -> int:
    # The MXU path consumes *unpacked* int8 mantissas: packed 1-bit weights
    # are materialized to K x N int8 before the dot (that unpacked footprint
    # is exactly what the fused kernel avoids).  XLA fuses the epilogue into
    # the dot's consumer, so the output is written once.
    return m * k + k * n + 4 * m * n + 8 * (m + n)


def _popcount_traffic(m, k, n, act_bits, weight_bits) -> int:
    # Bit-serial jnp path: each (i, j) plane pair re-reads plane i of the
    # acts and plane j of the weights — act planes are fetched weight_bits
    # times and vice versa (no cross-pair VMEM reuse outside a kernel).
    kw_bytes = 4 * packing.packed_len(k, 1)
    plane_reads = act_bits * weight_bits
    return (
        plane_reads * m * kw_bytes
        + plane_reads * kw_bytes * n
        + 4 * m * n
        + 8 * (m + n)
    )


def _mxu_scores(q_planes: jax.Array, k_planes: jax.Array, *, dh: int) -> jax.Array:
    """Scores-family core on the MXU: unpack the {0,1} planes to int8 and run
    a grouped int8 dot with int32 accumulation.  Bit-exact against the
    popcount cores (same integer math, different datapath), so the autotuner
    is free to pick either without touching numerics."""
    qb = packing.unpack_bits(q_planes, 1, dh, axis=-1, dtype=jnp.int8)
    kb = packing.unpack_bits(k_planes, 1, dh, axis=-1, dtype=jnp.int8)
    b, h, s, _ = qb.shape
    g = kb.shape[1]
    qg = qb.reshape(b, g, h // g, s, dh)
    out = jnp.einsum(
        "bgxsd,bgtd->bgxst", qg, kb, preferred_element_type=jnp.int32
    )
    return out.reshape(b, h, s, kb.shape[2])


@backend_registry.register_backend(
    "mxu",
    description="int8 dot_general on the MXU, int32 accumulation",
    traffic_model=_mxu_traffic,
    families=frozenset({"qmm", "scores"}),
    run_scores=_mxu_scores,
)
def _run_mxu(x: QuantTensor, w: QuantTensor, *, w_colsum=None, out_dtype=jnp.float32):
    return flow_abstraction.qmm_flow(
        x, w, int_matmul=None, w_colsum=w_colsum, out_dtype=out_dtype
    )


@backend_registry.register_backend(
    "popcount",
    description="bit-serial AND-popcount over packed uint32 lanes (jnp)",
    needs_unsigned_mantissas=True,
    traffic_model=_popcount_traffic,
)
def _run_popcount(
    x: QuantTensor, w: QuantTensor, *, w_colsum=None, out_dtype=jnp.float32
):
    # Popcount lanes consume raw unsigned planes: run the shared flow
    # abstraction without re-centering.  A caller-supplied colsum is valid
    # here only when re-centering is a no-op (1-bit weights).
    return flow_abstraction.qmm_flow(
        x,
        w,
        int_matmul=popcount_int_matmul,
        w_colsum=w_colsum if w.bits == 1 else None,
        out_dtype=out_dtype,
        recenter=False,
    )
