"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, local-attention window 2048, period (rec, rec, local-attn).
26 = 3*8 + 2 -> period x8 with a (rec, rec) remainder, which we place as a
PREFIX to keep the tail homogeneous (order within the 1:2 ratio is not
accuracy-relevant for systems purposes; noted in DESIGN.md).

Sub-quadratic (bounded window + linear recurrence) -> runs long_500k.
"""

from repro.configs.base import ArchConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_head=256,
        d_ff=7680,
        vocab_size=256000,
        prefix_layers=("r", "r"),
        pattern_period=("r", "r", "l"),
        window_size=2048,
        ffn_type="gelu_glu",
        pos_embedding="rope",
        rope_theta=10000.0,
        tie_embeddings=True,
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=1 << 20,
        source="[arXiv:2402.19427; hf]",
    )
)
