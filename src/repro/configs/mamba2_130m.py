"""mamba2-130m — attention-free SSM with SSD (state-space duality) mixers.

[arXiv:2405.21060; unverified]  24L d_model=768 vocab=50280, ssm_state=128,
expand=2 (d_inner 1536), head_dim=64 (24 SSD heads), conv width 4.
O(1) state per layer -> runs long_500k.

BETA applicability (DESIGN.md §5): projections (in/out) are act x weight
QMMs; the chunked SSD form's intra-chunk matmuls route through the
act x act engine (beyond-paper extension); the inter-chunk state recurrence
stays full-precision.
"""

from repro.configs.base import ArchConfig, QuantConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=24,  # SSD heads (d_inner / head_dim)
        n_kv_heads=24,
        d_head=64,
        d_ff=0,  # no separate FFN in mamba2 blocks
        vocab_size=50280,
        pattern_period=("s",),
        ffn_type="gelu",
        pos_embedding="none",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
        quant=QuantConfig(act_bits=8, attn_act_bits=8, quantize_attention=False),
        max_seq=1 << 20,
        source="[arXiv:2405.21060; unverified]",
    )
)
