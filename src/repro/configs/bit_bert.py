"""The paper's own benchmark models: BiT / BinaryBERT / BiBERT (BERT-base).

[arXiv:2211.xx BiT / ACL'21 BinaryBERT / ICLR'22 BiBERT; paper Table II]
12L d_model=768 12H d_ff=3072 vocab=30522, bidirectional encoder, seq 128
(MNLI-m).  These drive the Table II / Fig. 5 reproduction benchmarks and the
QAT example; activation precision is the configurable engine's knob
(W1A1 / W1A2 / W1A4 / W1A8).
"""

from repro.configs.base import ArchConfig, QuantConfig, register

def _bert(name: str, act_bits: int) -> ArchConfig:
    return ArchConfig(
        name=name,
        family="encoder",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=30522,
        pattern_period=("g",),
        ffn_type="gelu",
        pos_embedding="learned",
        causal=False,
        quant=QuantConfig(
            act_bits=act_bits,
            attn_act_bits=act_bits,
            kv_cache_bits=8,
        ),
        max_seq=512,
        source="[paper Table II benchmarks]",
    )


CONFIG = register(_bert("bit-bert-base", 1))
CONFIG_W1A2 = register(_bert("bit-bert-base-a2", 2))
CONFIG_W1A4 = register(_bert("bit-bert-base-a4", 4))
CONFIG_W1A8 = register(_bert("bit-bert-base-a8", 8))
