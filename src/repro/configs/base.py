"""Architecture / shape / quantization config schema.

Every assigned architecture is a frozen :class:`ArchConfig`; the model zoo
(``repro.models.model_zoo``) builds params and step functions from it, the
launcher selects one by ``--arch <id>``, and the dry-run sweeps
``(arch x input-shape x mesh)``.

Layer patterns: a transformer stack is ``prefix_layers`` (unrolled) followed
by ``pattern_period`` repeated ``(n_layers - len(prefix)) / len(period)``
times (lowered as one ``lax.scan`` over stacked period params — keeps HLO
size bounded for 60+-layer models, which matters both for compile time and
for the dry-run's 512-way SPMD partitioning).

Block kinds:
  "g"   global attention + dense FFN
  "l"   local (sliding-window) attention + dense FFN
  "r"   RG-LRU recurrent block + dense FFN        (recurrentgemma)
  "s"   Mamba-2 SSD mixer (no separate FFN)       (mamba2)
  "Md"  MLA attention + dense FFN                 (deepseek dense layers)
  "Mm"  MLA attention + MoE FFN                   (deepseek MoE layers)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = [
    "QuantConfig",
    "MoEConfig",
    "SSMConfig",
    "EncoderConfig",
    "ArchConfig",
    "InputShape",
    "LM_SHAPES",
    "register",
    "get_config",
    "list_configs",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """BETA quantization spec — which QMMs are quantized and how.

    ``act_bits`` selects the engine's precision mode (W1A{1,2,4,8});
    ``attn_act_bits`` covers the act x act QMMs (QK^T, PV); ``kv_cache_bits``
    is the serving-side KV compression (8 -> int8 lanes, 4 -> packed nibbles).
    Non-QMM ops (softmax, norms, activations, routers, recurrences) stay full
    precision, as in the paper.
    """

    enabled: bool = True
    weight_bits: int = 1
    act_bits: int = 8
    attn_act_bits: int = 8
    quantize_attention: bool = True
    kv_cache_bits: int = 8
    # integer-MM backend: "auto" or any name registered in
    # core.backend_registry ("mxu", "popcount", "pallas", "fused", ...).
    # "auto" routes through the measured autotune cache (core.dispatch).
    backend: str = "mxu"
    # per-layer backend overrides: ((fnmatch pattern over the layer name,
    # backend), ...) — first match wins, e.g. (("ffn.down", "popcount"),
    # ("attn.*", "mxu")).  Unmatched layers use ``backend``.
    backend_overrides: Tuple[Tuple[str, str], ...] = ()
    # QAT weights are binarized+bit-packed BEFORE the FSDP all-gather, so
    # the wire carries 1-bit words instead of fp32 latents (32x — the
    # BETA storage insight applied to the collective fabric; §Perf).
    prebinarize_gather: bool = False

    @staticmethod
    def known_backends() -> Tuple[str, ...]:
        """Valid integer-MM backend names: "auto" (measured dispatch,
        core.dispatch) plus every backend in ``core.backend_registry``."""
        from repro.core import backend_registry

        return ("auto",) + backend_registry.backend_names()

    def __post_init__(self):
        known = self.known_backends()
        if self.backend not in known:
            raise ValueError(f"unknown backend {self.backend!r}; valid: {known}")
        for pattern, b in self.backend_overrides:
            if b not in known:
                raise ValueError(
                    f"backend_overrides[{pattern!r}] names unknown backend "
                    f"{b!r}; valid: {known}"
                )

    @property
    def mode_name(self) -> str:
        return f"W{self.weight_bits}A{self.act_bits}"

    def backend_for(self, layer_name: str = "") -> str:
        """Resolve the integer-MM backend for a named layer site.

        ``layer_name`` is the dotted site name the model layer passes down
        (e.g. "ffn.up", "attn.o"); unnamed sites resolve to the default.
        """
        if layer_name:
            import fnmatch

            for pattern, b in self.backend_overrides:
                if fnmatch.fnmatchcase(layer_name, pattern):
                    return b
        return self.backend


FLOAT_QUANT = QuantConfig(enabled=False)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert_ff: int
    d_shared_ff: int = 0  # defaults to d_expert_ff * n_shared
    capacity_factor: float = 1.25
    router_scoring: str = "softmax"  # "softmax" | "sigmoid" (deepseek-v3)
    route_scale: float = 1.0

    @property
    def shared_ff(self) -> int:
        return self.d_shared_ff or self.d_expert_ff * self.n_shared


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention geometry."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 -> direct q projection (v2-lite)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Frontend/encoder for enc-dec (whisper) and VLM (internvl2) archs.

    Per the assignment spec the modality frontend is a STUB: ``input_specs``
    provides precomputed frame/patch embeddings of shape
    ``(batch, n_positions, d_model)`` (projected in by a single stub linear),
    and for whisper a full transformer encoder runs on top for cross-attn.
    """

    kind: str  # "audio_stub" | "patch_stub"
    n_positions: int  # 1500 audio frames / vision patches per image
    n_layers: int = 0  # transformer layers on top of the stub (whisper: 4)
    d_input: int = 0  # stub embedding dim before projection (0 -> d_model)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    pattern_period: Tuple[str, ...] = ("g",)
    prefix_layers: Tuple[str, ...] = ()
    window_size: int = 0
    qk_norm: bool = False
    ffn_type: str = "silu_glu"  # "gelu" | "silu_glu" | "gelu_glu"
    rope_theta: float = 10000.0
    local_rope_theta: float = 0.0  # gemma3 uses a different theta locally
    pos_embedding: str = "rope"  # "rope" | "learned" | "sinusoidal" | "none"
    causal: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    quant: QuantConfig = QuantConfig()
    # perf knobs (EXPERIMENTS.md §Perf): attention-score / logits compute
    # dtypes — "f32" (baseline) or "bf16" (hillclimbed)
    attn_scores_dtype: str = "f32"
    logits_dtype: str = "f32"
    # GQA layout: "grouped" contracts against un-expanded KV (best when
    # n_kv_heads divides the model axis); "expand" repeats KV to H heads
    # (best when kvH < |model|: the grouped (kvH, g) reshape of a 16-way
    # sharded head dim triggers XLA involuntary full rematerialization).
    gqa_mode: str = "grouped"
    mtp_depth: int = 0  # deepseek-v3 multi-token prediction heads
    max_seq: int = 131072
    source: str = ""  # provenance note: [source; verified-tier]

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        n_pattern = self.n_layers - len(self.prefix_layers)
        if n_pattern < 0 or (
            len(self.pattern_period) and n_pattern % len(self.pattern_period)
        ):
            raise ValueError(
                f"{self.name}: {self.n_layers} layers does not decompose into "
                f"prefix {self.prefix_layers} + k * period {self.pattern_period}"
            )

    @property
    def n_periods(self) -> int:
        return (self.n_layers - len(self.prefix_layers)) // len(self.pattern_period)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return self.prefix_layers + self.pattern_period * self.n_periods

    @property
    def is_sub_quadratic(self) -> bool:
        """True when no layer does full attention over the whole sequence
        (SSM / linear-recurrence / bounded-window only) — the long_500k
        eligibility rule (DESIGN.md §5)."""
        return all(k in ("l", "r", "s") for k in self.layer_kinds)

    @property
    def has_decoder(self) -> bool:
        return self.family != "encoder"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6*N*D accounting."""
        d, ff = self.d_model, self.d_ff
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds:
            if kind in ("g", "l"):
                attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
                attn += self.n_heads * self.d_head * d
                ffp = self._ffn_params(ff)
                total += attn + ffp
            elif kind in ("Md", "Mm"):
                m = self.mla
                q = (
                    d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    if m.q_lora_rank
                    else d * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                )
                kv = d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank * self.n_heads * (
                    m.qk_nope_dim + m.v_head_dim
                )
                o = self.n_heads * m.v_head_dim * d
                total += q + kv + o
                if kind == "Md":
                    total += self._ffn_params(ff)
                else:
                    e = self.moe
                    total += e.n_routed * self._ffn_params(e.d_expert_ff)
                    total += self._ffn_params(e.shared_ff)
                    total += d * e.n_routed  # router
            elif kind == "r":
                di = self.d_model  # RG-LRU width = d_model (recurrentgemma)
                total += 2 * d * di + di * d + 3 * di  # in/gate/out + gates
                total += self._ffn_params(ff)
            elif kind == "s":
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                total += d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
                total += di * d  # out_proj
                total += di * s.d_conv + nh * 2  # conv + A, D
        return total

    def _ffn_params(self, ff: int) -> int:
        mult = 3 if self.ffn_type.endswith("glu") else 2
        return mult * self.d_model * ff

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        e = self.moe
        n_moe_layers = sum(1 for k in self.layer_kinds if k == "Mm")
        inactive = (e.n_routed - e.top_k) * self._ffn_params(e.d_expert_ff)
        return total - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


#: The assigned LM shape grid (each arch runs all four, minus documented skips).
LM_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME: Dict[str, InputShape] = {s.name: s for s in LM_SHAPES}

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # populate the registry on first use
    from repro import configs as _pkg  # noqa: F401  (imports all modules)

    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}") from None


def list_configs() -> Tuple[str, ...]:
    from repro import configs as _pkg  # noqa: F401

    return tuple(sorted(_REGISTRY))
