"""Assigned-architecture configs (+ the paper's own benchmarks).

Importing this package populates the registry; ``base.get_config(name)`` /
``base.list_configs()`` are the public API.  ``--arch <id>`` anywhere in the
launcher resolves through here.
"""

from repro.configs import base
from repro.configs.base import ArchConfig, InputShape, LM_SHAPES, get_config, list_configs

# one module per assigned architecture (registration side-effect)
from repro.configs import (  # noqa: F401
    bit_bert,
    deepseek_v2_lite_16b,
    deepseek_v3_671b,
    gemma3_27b,
    granite_8b,
    internvl2_2b,
    mamba2_130m,
    mistral_nemo_12b,
    qwen3_32b,
    recurrentgemma_2b,
    whisper_tiny,
)

#: The ten assigned architectures (dry-run / roofline grid rows).
ASSIGNED = (
    "recurrentgemma-2b",
    "internvl2-2b",
    "deepseek-v3-671b",
    "deepseek-v2-lite-16b",
    "whisper-tiny",
    "mistral-nemo-12b",
    "granite-8b",
    "gemma3-27b",
    "qwen3-32b",
    "mamba2-130m",
)

__all__ = [
    "ArchConfig",
    "InputShape",
    "LM_SHAPES",
    "ASSIGNED",
    "get_config",
    "list_configs",
    "base",
]
