"""granite-8b — llama-architecture dense decoder (IBM Granite code models).

[arXiv:2405.04324; hf]  36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152.  Pure full attention -> long_500k skipped (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=49152,
        pattern_period=("g",),
        ffn_type="silu_glu",
        rope_theta=10000000.0,
        tie_embeddings=True,
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=131072,
        source="[arXiv:2405.04324; hf]",
    )
)
