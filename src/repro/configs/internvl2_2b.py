"""internvl2-2b — InternViT frontend (stub) + InternLM2-1.8b LM backbone.

[arXiv:2404.16821; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  Per the assignment the vision frontend is a STUB:
``input_specs`` supplies precomputed patch embeddings (InternViT-300M's
1024-dim pooled patches for one 448x448 tile -> 256 tokens) which a single
stub projection maps into the LM's embedding space; the transformer backbone
is the deliverable.
"""

from repro.configs.base import ArchConfig, EncoderConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        pattern_period=("g",),
        ffn_type="silu_glu",
        rope_theta=1000000.0,
        tie_embeddings=False,
        encoder=EncoderConfig(kind="patch_stub", n_positions=256, d_input=1024),
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=32768,
        source="[arXiv:2404.16821; hf]",
    )
)
