"""mistral-nemo-12b — dense GQA decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072, head_dim=128 (explicit — 32*128 != 5120),
rope theta 1e6.  Pure full attention -> long_500k skipped (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="mistral-nemo-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=131072,
        pattern_period=("g",),
        ffn_type="silu_glu",
        rope_theta=1000000.0,
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=131072,
        source="[hf:mistralai/Mistral-Nemo-Base-2407; hf]",
    )
)
