"""gemma3-27b — dense decoder with 5:1 local:global attention interleave.

[hf:google/gemma-3-1b-pt; unverified]  62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144, qk-norm, sliding window 1024 on local layers,
rope theta 1M global / 10k local.  62 = 6*10 + 2 -> (l,l,l,l,l,g) x10 with
an (l,l) prefix.  Global layers attend over the full cache -> long_500k
skipped (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_head=128,
        d_ff=21504,
        vocab_size=262144,
        prefix_layers=("l", "l"),
        pattern_period=("l", "l", "l", "l", "l", "g"),
        window_size=1024,
        qk_norm=True,
        ffn_type="gelu_glu",
        rope_theta=1000000.0,
        local_rope_theta=10000.0,
        tie_embeddings=True,
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=131072,
        source="[hf:google/gemma-3-1b-pt; unverified]",
    )
)
