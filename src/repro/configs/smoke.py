"""Reduced smoke variants of every assigned config.

Same family/block pattern, tiny dims: used by the per-arch smoke tests
(tests/test_arch_smoke.py) to run one real forward/train/serve step on CPU.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation), per the assignment.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, EncoderConfig, MLAConfig, MoEConfig, SSMConfig


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Shrink a full config to laptop scale, preserving its block pattern
    (one period + prefix), head grouping ratios, and feature set."""
    n_layers = len(cfg.prefix_layers) + len(cfg.pattern_period)
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, heads * cfg.n_kv_heads // cfg.n_heads)
    d_head = 16
    d_model = 64
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        d_head=d_head,
        d_ff=max(1, 128 if cfg.d_ff else 0),
        vocab_size=256,
        window_size=8 if cfg.window_size else 0,
        max_seq=128,
    )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            kv_lora_rank=16,
            q_lora_rank=8 if cfg.mla.q_lora_rank else 0,
            qk_nope_dim=16,
            qk_rope_dim=8,
            v_head_dim=16,
        )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_routed=8,
            n_shared=min(cfg.moe.n_shared, 2),
            top_k=2,
            d_expert_ff=32,
            router_scoring=cfg.moe.router_scoring,
            route_scale=cfg.moe.route_scale,
        )
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16
        )
        changes["n_heads"] = (d_model * 2) // 16
        changes["n_kv_heads"] = changes["n_heads"]
        changes["d_ff"] = 0
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(
            kind=cfg.encoder.kind,
            n_positions=12,
            n_layers=min(cfg.encoder.n_layers, 2),
            d_input=24 if cfg.encoder.d_input else 0,
        )
    return dataclasses.replace(cfg, **changes)
