"""whisper-tiny — encoder-decoder ASR transformer, conv frontend stubbed.

[arXiv:2212.04356; unverified]  4L encoder + 4L decoder, d_model=384 6H
(kv=6) d_ff=1536 vocab=51865.  The conv1d mel frontend is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
(1500 frames x 384 after the conv stack's 2x downsampling of 3000 mel
frames); the encoder transformer + decoder with cross-attention are real.
Sinusoidal encoder positions, learned decoder positions (both non-rope).
"""

from repro.configs.base import ArchConfig, EncoderConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,  # decoder layers; encoder depth in EncoderConfig
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        pattern_period=("g",),
        ffn_type="gelu",
        pos_embedding="learned",
        tie_embeddings=True,
        encoder=EncoderConfig(kind="audio_stub", n_positions=1500, n_layers=4),
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=448,
        source="[arXiv:2212.04356; unverified]",
    )
)
