"""deepseek-v3-671b — MLA + 256-expert MoE (1 shared + 256 routed, top-8) + MTP.

[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(expert)=2048
vocab=129280.  First 3 layers dense (d_ff 18432), remaining 58 MoE.
MLA: kv_lora 512, q_lora 1536, qk_nope 128, qk_rope 64, v_head 128.
Router: sigmoid scoring with top-8 of 256 routed + 1 shared expert.
MTP: one extra multi-token-prediction head (depth 1), training-loss only.
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_head=128,
        d_ff=18432,  # the 3 dense layers; experts use moe.d_expert_ff
        vocab_size=129280,
        prefix_layers=("Md", "Md", "Md"),
        pattern_period=("Mm",),
        ffn_type="silu_glu",
        rope_theta=10000.0,
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_routed=256,
            n_shared=1,
            top_k=8,
            d_expert_ff=2048,
            router_scoring="sigmoid",
            route_scale=2.5,
        ),
        mtp_depth=1,
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=131072,
        source="[arXiv:2412.19437; hf]",
    )
)
