"""qwen3-32b — dense GQA decoder with qk-norm.

[hf:Qwen/Qwen3-8B; hf]  64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, head_dim=128, per-head RMSNorm on q and k before rope.
Pure full attention -> long_500k skipped (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=25600,
        vocab_size=151936,
        pattern_period=("g",),
        qk_norm=True,
        ffn_type="silu_glu",
        rope_theta=1000000.0,
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=131072,
        source="[hf:Qwen/Qwen3-8B; hf]",
    )
)
