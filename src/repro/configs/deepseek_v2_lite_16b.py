"""deepseek-v2-lite-16b — MLA + 64-expert MoE (2 shared + 64 routed, top-6).

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff(expert)=1408
vocab=102400.  First layer dense (d_ff 10944), remaining 26 MoE.
MLA: kv_lora 512, q projected directly (no q LoRA), qk_nope 128,
qk_rope 64, v_head 128.  Softmax router, top-6.
(The assignment banner lists both "64e top-6" and "160 routed"; we follow
the HF deepseek-v2-lite config: 64 routed experts, 2 shared, top-6 —
the 160-routed figure belongs to full deepseek-v2.)
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, QuantConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        prefix_layers=("Md",),
        pattern_period=("Mm",),
        ffn_type="silu_glu",
        rope_theta=10000.0,
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=0,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_routed=64,
            n_shared=2,
            top_k=6,
            d_expert_ff=1408,
            router_scoring="softmax",
        ),
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=163840,
        source="[arXiv:2405.04434; hf]",
    )
)
