"""Production mesh construction.

Target: TPU v5e pods of 256 chips, arranged (data=16, model=16) per pod;
multi-pod adds a leading pure-DP ``pod`` axis (2 pods = 512 chips for the
dry-run; the axis generalizes to N pods).  Axis roles are documented in
runtime/sharding.py.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: every axis is Auto already
    AxisType = None

__all__ = ["make_production_mesh", "make_host_mesh", "abstract_mesh"]


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """Device-free mesh for spec-legality checks, across jax API versions.

    jax >= 0.5 takes ``AbstractMesh(shape, axis_names)``; 0.4.x takes a
    single tuple of ``(name, size)`` pairs.
    """
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) 'data','model' per pod; (2, 16, 16) with a 'pod' DP axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} exceeds {n} devices")
    return _make_mesh((data, model), ("data", "model"))
