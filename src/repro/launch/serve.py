"""Batched serving driver (the accelerator's role: binary-weight inference).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --requests 8 --max-new 16

Initializes a model, runs the offline weight pipeline (binarize -> bit-pack
-> colsum fold, the paper's 'performed offline' step), and serves a queue of
requests through the slot-managed continuous-batching engine.

Two request sources:

* fixed queue (default): ``--requests`` identical-shape prompts, all
  arriving at t=0 — the quick eyeball run.
* open-loop traffic (``--traffic``): seeded Poisson arrivals with uniform
  prompt/output length ranges (runtime.traffic) — the serve_bench workload;
  add ``--bench-out`` to persist the BENCH_serve.json summary.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine
from repro.runtime.traffic import (
    TrafficConfig,
    generate_requests,
    save_bench,
    summarize_bench,
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as slots emit them (per-request callbacks)")
    ap.add_argument("--autotune-cache", default=None,
                    help="JSON path for persisted QMM autotune verdicts")
    # open-loop traffic mode
    ap.add_argument("--traffic", action="store_true",
                    help="Poisson open-loop workload instead of the fixed queue")
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--bench-out", default=None,
                    help="write the BENCH_serve.json summary here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    rng = np.random.default_rng(args.seed)
    params = Z.init_params(jax.random.PRNGKey(args.seed), cfg)
    serving = Z.prepare_serving_params(params, cfg)

    # packed-weight footprint accounting (the paper's compression headline)
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    full, packed = nbytes(params), nbytes(serving)
    print(
        f"[serve] weights: fp32 latent {full/1e6:.1f} MB -> packed {packed/1e6:.1f} MB"
        f" ({full/packed:.1f}x)"
    )

    engine = ServeEngine(
        cfg,
        serving,
        batch_slots=args.slots,
        max_len=args.max_len,
        seed=args.seed,
        autotune_cache_path=args.autotune_cache,
    )
    if args.traffic:
        tc = TrafficConfig(
            n_requests=args.requests,
            rate_rps=args.rate,
            prompt_len=(max(1, args.prompt_len // 2), args.prompt_len),
            new_tokens=(max(1, args.max_new // 2), args.max_new),
            temperature=args.temperature,
            seed=args.seed,
        )
        reqs = generate_requests(tc, cfg.vocab_size)
    else:
        reqs = [
            Request(
                prompt=rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(
                    np.int32
                ),
                max_new_tokens=args.max_new,
                temperature=args.temperature,
            )
            for _ in range(args.requests)
        ]
    if args.stream:
        for i, r in enumerate(reqs):
            r.on_token = lambda tok, i=i: print(f"  [stream] req{i} -> {tok}")

    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s incl. compile)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt[:4]={np.asarray(r.prompt)[:4].tolist()} -> out[:8]={r.output[:8]}")
    if args.bench_out:
        summary = summarize_bench(
            done, dt,
            {"arch": args.arch, "smoke": bool(args.smoke),
             "batch_slots": args.slots, "max_len": args.max_len,
             "traffic": args.traffic},
        )
        save_bench(args.bench_out, summary)
        print(f"[serve] bench summary -> {args.bench_out} "
              f"(rps={summary['rps']:.2f}, p50={summary['p50_ms']:.1f}ms, "
              f"p99={summary['p99_ms']:.1f}ms)")


if __name__ == "__main__":
    main()
