"""Batched serving driver (the accelerator's role: binary-weight inference).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --requests 8 --max-new 16

Initializes a model, runs the offline weight pipeline (binarize -> bit-pack
-> colsum fold, the paper's 'performed offline' step), and serves a queue of
synthetic requests through the slot-batched engine.
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config, list_configs
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    rng = np.random.default_rng(args.seed)
    params = Z.init_params(jax.random.PRNGKey(args.seed), cfg)
    serving = Z.prepare_serving_params(params, cfg)

    # packed-weight footprint accounting (the paper's compression headline)
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    full, packed = nbytes(params), nbytes(serving)
    print(
        f"[serve] weights: fp32 latent {full/1e6:.1f} MB -> packed {packed/1e6:.1f} MB"
        f" ({full/packed:.1f}x)"
    )

    engine = ServeEngine(
        cfg, serving, batch_slots=args.slots, max_len=args.max_len, seed=args.seed
    )
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(
                np.int32
            ),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
        )
        for _ in range(args.requests)
    ]
    import time

    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s incl. compile)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt[:4]={r.prompt[:4].tolist()} -> out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
