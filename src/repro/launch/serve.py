"""Batched serving driver (the accelerator's role: binary-weight inference).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --requests 8 --max-new 16

Initializes a model, runs the offline weight pipeline (binarize -> bit-pack
-> colsum fold, the paper's 'performed offline' step), and serves a queue of
requests through the slot-managed continuous-batching engine.

Two request sources:

* fixed queue (default): ``--requests`` identical-shape prompts, all
  arriving at t=0 — the quick eyeball run.
* open-loop traffic (``--traffic``): seeded Poisson arrivals with uniform
  prompt/output length ranges (runtime.traffic) — the serve_bench workload;
  add ``--bench-out`` to persist the BENCH_serve.json summary.

Robustness knobs (docs/serving-robustness.md):

* ``--fault-plan '{"decode_fail_ticks": [3]}'`` — inject a deterministic
  failure schedule (runtime.faults.FaultPlan JSON) into the run.
* ``--deadline-s 2.0`` — per-request deadline from arrival; expired
  requests terminate with state "deadline" instead of holding a slot.
* ``--snapshot-every 8 --snapshot-dir /tmp/serve-snap`` — checkpoint the
  full engine state (queue, slot caches/cursors/budgets, sampler states)
  every 8 decode ticks.

Crash recovery — a killed process finishes its in-flight requests
token-for-token identical to an uninterrupted run::

  # serving process (killed mid-batch: SIGKILL, OOM, preemption, ...)
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --requests 8 --max-new 24 --snapshot-every 4 --snapshot-dir /tmp/snap

  # replacement process: same arch/seed/slots, --resume instead of a queue
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --snapshot-dir /tmp/snap --resume
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.faults import parse_fault_plan
from repro.runtime.serve_loop import Request, ServeEngine
from repro.runtime.traffic import (
    TrafficConfig,
    generate_requests,
    save_bench,
    summarize_bench,
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as slots emit them (per-request callbacks)")
    ap.add_argument("--autotune-cache", default=None,
                    help="JSON path for persisted QMM autotune verdicts")
    # open-loop traffic mode
    ap.add_argument("--traffic", action="store_true",
                    help="Poisson open-loop workload instead of the fixed queue")
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--bench-out", default=None,
                    help="write the BENCH_serve.json summary here")
    # robustness knobs (docs/serving-robustness.md)
    ap.add_argument("--fault-plan", default=None,
                    help="JSON FaultPlan (runtime.faults) injected into the run")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds from arrival")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot engine state every K decode ticks (0 = off)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="CheckpointManager directory for engine snapshots")
    ap.add_argument("--resume", action="store_true",
                    help="resume in-flight requests from --snapshot-dir instead "
                         "of serving a fresh queue")
    args = ap.parse_args()
    if args.resume and not args.snapshot_dir:
        ap.error("--resume requires --snapshot-dir")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    rng = np.random.default_rng(args.seed)
    params = Z.init_params(jax.random.PRNGKey(args.seed), cfg)
    serving = Z.prepare_serving_params(params, cfg)

    # packed-weight footprint accounting (the paper's compression headline)
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    full, packed = nbytes(params), nbytes(serving)
    print(
        f"[serve] weights: fp32 latent {full/1e6:.1f} MB -> packed {packed/1e6:.1f} MB"
        f" ({full/packed:.1f}x)"
    )

    engine = ServeEngine(
        cfg,
        serving,
        batch_slots=args.slots,
        max_len=args.max_len,
        seed=args.seed,
        autotune_cache_path=args.autotune_cache,
        fault_plan=parse_fault_plan(args.fault_plan),
        snapshot_every=args.snapshot_every,
        snapshot_dir=args.snapshot_dir,
    )
    if args.resume:
        t0 = time.perf_counter()
        done = engine.resume()
        dt = time.perf_counter() - t0
    else:
        if args.traffic:
            tc = TrafficConfig(
                n_requests=args.requests,
                rate_rps=args.rate,
                prompt_len=(max(1, args.prompt_len // 2), args.prompt_len),
                new_tokens=(max(1, args.max_new // 2), args.max_new),
                temperature=args.temperature,
                deadline_s=args.deadline_s,
                seed=args.seed,
            )
            reqs = generate_requests(tc, cfg.vocab_size)
        else:
            reqs = [
                Request(
                    prompt=rng.integers(
                        0, cfg.vocab_size, size=(args.prompt_len,)
                    ).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature,
                    deadline_s=args.deadline_s,
                )
                for _ in range(args.requests)
            ]
        if args.stream:
            for i, r in enumerate(reqs):
                r.on_token = lambda tok, i=i: print(f"  [stream] req{i} -> {tok}")

        t0 = time.perf_counter()
        done = engine.run(reqs)
        dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s incl. compile)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt[:4]={np.asarray(r.prompt)[:4].tolist()} -> out[:8]={r.output[:8]}")
    if args.bench_out:
        summary = summarize_bench(
            done, dt,
            {"arch": args.arch, "smoke": bool(args.smoke),
             "batch_slots": args.slots, "max_len": args.max_len,
             "traffic": args.traffic},
            events=engine.last_events,
        )
        save_bench(args.bench_out, summary)
        print(f"[serve] bench summary -> {args.bench_out} "
              f"(rps={summary['rps']:.2f}, p50={summary['p50_ms']:.1f}ms, "
              f"p99={summary['p99_ms']:.1f}ms)")


if __name__ == "__main__":
    main()
