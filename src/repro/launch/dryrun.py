import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 TPU v5e pods; ``jax.jit(...).lower()``
+ ``.compile()`` must succeed, and the compiled artifact yields

  * ``memory_analysis()``  — per-device bytes (does it fit 16 GB/chip?)
  * ``cost_analysis()``    — HLO FLOPs / bytes for the roofline terms
  * collective bytes       — parsed from the optimized HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand sizes)

Artifacts are cached as JSON per cell under --out (1-core container:
compiles are the long pole; re-runs skip completed cells).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
  PYTHONPATH=src python -m repro.launch.dryrun --arch smoke       # CI cell

``--arch smoke`` lowers+compiles a reduced (smoke-variant) config on a tiny
train shape — the CI-sized proof that the whole lower/compile/artifact
pipeline works, in seconds instead of hours.
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.configs.base import ArchConfig, InputShape, SHAPES_BY_NAME
from repro.models import model_zoo as Z

# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Abstract inputs for one cell. Training: the data batch; serving:
    the request batch (prompt tokens or decode tokens)."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.encoder is not None:
        d_in = cfg.encoder.d_input or cfg.d_model
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.n_positions, d_in), jnp.float32
        )
    return specs


def skip_reason(cfg: ArchConfig, shape: InputShape) -> str | None:
    """Documented skips (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.is_sub_quadratic:
        return "long_500k requires sub-quadratic attention (DESIGN.md §5)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return "encoder-only arch has no decode step"
    return None


# ---------------------------------------------------------------------------
# collective-byte accounting from optimized HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by kind.

    HLO lines look like:
      ``%ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups=...``
    The result shape of a collective equals (or bounds) the moved payload
    per device; we also record op counts.
    """
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            if f" {kind}(" in stripped or f"{kind}-start(" in stripped:
                lhs = stripped.split("=", 1)
                shape_part = lhs[1] if len(lhs) == 2 else stripped
                shape_part = shape_part.split(kind)[0]
                out[kind]["bytes"] += _shape_bytes(shape_part)
                out[kind]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# scan-body correction
# ---------------------------------------------------------------------------
#
# XLA's cost_analysis reports PER-DEVICE numbers and counts a while/scan body
# ONCE (verified empirically: a scan of 8 matmuls reports 1 matmul of flops).
# Our stacks lower the repeating period as one lax.scan over n_periods, so a
# cell's raw numbers undercount by (n_periods - 1) x (one period body).  We
# lower the period body separately under the same mesh/shardings and publish
#   corrected = raw + (n_periods - 1) * body
# for flops, bytes and collective bytes.  (Residual scan-once undercount:
# the SSD inter-chunk state scan's tiny state-passing einsums — documented.)


def lower_period_body(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    """Per-device cost of ONE period iteration for this cell's step kind."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import transformer as T
    from repro.runtime import sharding as SH

    if not cfg.n_periods:
        return {"flops": 0.0, "bytes_accessed": 0.0, "collectives": 0, "n_periods": 0}

    b, s = shape.global_batch, shape.seq_len
    s_eff = 1 if shape.kind == "decode" else s
    serving = shape.kind != "train"

    def one_period(pslice, x, positions, caches):
        aux = jnp.float32(0.0)
        new_caches = []
        mode = "serve" if serving else "train"
        for j, kind in enumerate(cfg.pattern_period):
            cj = caches[j] if caches is not None else None
            x, cj, a = T.block_apply(pslice[j], x, cfg, kind, mode, positions, cj)
            aux += a
            new_caches.append(cj if cj is not None else 0)
        return x, aux, new_caches

    def build_pslice(key):
        ks = jax.random.split(key, len(cfg.pattern_period))
        blocks = [
            T.init_block(ks[j], cfg, kind) for j, kind in enumerate(cfg.pattern_period)
        ]
        if serving:
            blocks = [Z.prepare_serving_params(b_, cfg) for b_ in blocks]
        return blocks

    pslice = jax.eval_shape(build_pslice, jax.random.PRNGKey(0))
    x = jax.ShapeDtypeStruct((b, s_eff, cfg.d_model), jnp.bfloat16)
    positions = jax.ShapeDtypeStruct((b, s_eff), jnp.int32)
    caches = None
    if shape.kind in ("prefill", "decode"):
        caches = jax.eval_shape(
            lambda: [
                T.init_block_cache(b, s, cfg, kind) for kind in cfg.pattern_period
            ]
        )

    if shape.kind == "train":
        def fn(pslice, x, positions):
            def scalar(ps, xx):
                y, aux, _ = one_period(ps, xx, positions, None)
                return jnp.sum(y.astype(jnp.float32)) + aux

            return jax.grad(scalar, argnums=(0, 1))(pslice, x)
        args = (pslice, x, positions)
    else:
        def fn(pslice, x, positions, caches):
            return one_period(pslice, x, positions, caches)
        args = (pslice, x, positions, caches)

    p_sh = SH.params_shardings(pslice, mesh, fsdp=not serving)
    x_sh = NamedSharding(
        mesh, P(*(list(SH.logical_batch_spec(b, s_eff, mesh)) + [None]))
    )
    pos_sh = NamedSharding(mesh, SH.logical_batch_spec(b, s_eff, mesh))
    in_sh = (p_sh, x_sh, pos_sh) + ((SH.cache_shardings(caches, mesh, b),) if caches is not None else ())
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops") or 0.0),
        "bytes_accessed": float(cost.get("bytes accessed") or 0.0),
        "collectives": coll,
        "n_periods": cfg.n_periods,
    }


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------


def _abstract_params(cfg: ArchConfig, serving: bool):
    def build(key):
        p = Z.init_params(key, cfg)
        return Z.prepare_serving_params(p, cfg) if serving else p

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def lower_cell(cfg: ArchConfig, shape: InputShape, mesh, accum_steps: int = 1):
    """Build + lower the step function for one cell. Returns (lowered, meta)."""
    from repro.optim import adamw
    from repro.runtime import serve_loop, sharding as SH, train_loop

    specs = input_specs(cfg, shape)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        tcfg = train_loop.TrainConfig(
            optimizer=adamw.AdamWConfig(), accum_steps=accum_steps
        )
        step = train_loop.make_train_step(cfg, tcfg, mesh, specs)
        params = _abstract_params(cfg, serving=False)
        opt = jax.eval_shape(lambda p: adamw.init_state(p), params)
        with mesh:
            lowered = step.lower(params, opt, specs)
        return lowered, {"step": "train_step", "accum": accum_steps}

    params = _abstract_params(cfg, serving=True)
    if shape.kind == "prefill":
        fn = serve_loop.make_prefill(cfg, mesh, b, s, s)
        cache = jax.eval_shape(lambda: Z.init_cache(b, s, cfg))
        args = (params, specs["tokens"], cache)
        if "frontend" in specs:
            args = args + (specs["frontend"],)
        with mesh:
            lowered = fn.lower(*args)
        return lowered, {"step": "prefill"}

    # decode: one new token against a cache of seq_len
    fn = serve_loop.make_decode_step(cfg, mesh, b, s)
    cache = jax.eval_shape(lambda: Z.init_cache(b, s, cfg))
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    with mesh:
        lowered = fn.lower(params, tok, cache)
    return lowered, {"step": "decode_step"}


OPT_TRANSFORMS = {
    # §Perf hillclimb knobs — each is one hypothesis->change iteration
    "scores_bf16": dict(attn_scores_dtype="bf16"),
    "logits_bf16": dict(logits_dtype="bf16"),
    "gqa_expand": dict(gqa_mode="expand"),
    "packed_gather": "quant",  # binarize+pack before the FSDP all-gather
}


def apply_opts(cfg: ArchConfig, opts) -> ArchConfig:
    import dataclasses as _dc

    for o in opts or ():
        if o.startswith("accum"):
            continue  # handled by accum_steps
        if o == "packed_gather":
            cfg = _dc.replace(
                cfg, quant=_dc.replace(cfg.quant, prebinarize_gather=True)
            )
            continue
        cfg = _dc.replace(cfg, **OPT_TRANSFORMS[o])
    return cfg


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    out_dir: str,
    accum_steps: int = 1,
    compile_: bool = True,
    opts=(),
) -> dict:
    from repro.launch.mesh import make_production_mesh

    if arch == "smoke":
        from repro.configs.smoke import smoke_variant

        cfg = apply_opts(smoke_variant(get_config("granite-8b")), opts)
    else:
        cfg = apply_opts(get_config(arch), opts)
    shape = SMOKE_SHAPE if shape_name == SMOKE_SHAPE.name else SHAPES_BY_NAME[shape_name]
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "opts": list(opts or ()),
        "time": time.time(),
    }
    reason = skip_reason(cfg, shape)
    if reason:
        record.update(status="skip", reason=reason)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    record["mesh_shape"] = dict(mesh.shape)
    try:
        t0 = time.time()
        lowered, meta = lower_cell(cfg, shape, mesh, accum_steps)
        record.update(meta)
        record["lower_s"] = round(time.time() - t0, 1)
        if not compile_:
            record["status"] = "lowered"
            return record
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 1)

        mem = compiled.memory_analysis()
        for field in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            record.setdefault("memory", {})[field] = getattr(mem, field, None)

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        record["cost"] = {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        }
        hlo = compiled.as_text()
        record["collectives"] = collective_bytes(hlo)
        record["hlo_lines"] = hlo.count("\n")
        # scan-body correction (see module comment): one extra small lowering
        t0 = time.time()
        try:
            record["period_body"] = lower_period_body(cfg, shape, mesh)
        except Exception as e:  # noqa: BLE001
            record["period_body"] = {"error": f"{type(e).__name__}: {e}"}
        record["body_lower_s"] = round(time.time() - t0, 1)
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    return record


def cell_path(out_dir: str, arch: str, shape: str, mesh_kind: str, suffix: str = "") -> str:
    tail = f"__{suffix}" if suffix else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{tail}.json")


#: The CI cell: reduced config, reduced shape — lower+compile in seconds.
SMOKE_SHAPE = InputShape("smoke", 128, 8, "train")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ASSIGNED) + ["smoke"], default=None)
    ap.add_argument(
        "--shape", choices=list(SHAPES_BY_NAME) + [SMOKE_SHAPE.name], default=None
    )
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--no-compile", action="store_true", help="lower only")
    ap.add_argument("--opt", action="append", default=[], choices=list(OPT_TRANSFORMS))
    ap.add_argument("--suffix", default="", help="artifact suffix for variants")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ASSIGNED) if (args.all or args.arch is None) else [args.arch]
    if args.arch == "smoke" and args.shape is None and not args.all:
        shapes = [SMOKE_SHAPE.name]
    else:
        shapes = list(SHAPES_BY_NAME) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(args.out, arch, shape, mesh_kind, args.suffix)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[cached] {arch} {shape} {mesh_kind}: {prev['status']}")
                        continue
                print(f"[run] {arch} {shape} {mesh_kind} ...", flush=True)
                rec = run_cell(
                    arch, shape, mesh_kind, args.out, args.accum_steps,
                    compile_=not args.no_compile, opts=args.opt,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                msg = rec["status"]
                if rec["status"] == "ok":
                    msg += (
                        f" flops={rec['cost']['flops']:.3e}"
                        f" coll={rec['collectives']['total_bytes']:.3e}B"
                        f" lower={rec['lower_s']}s compile={rec['compile_s']}s"
                    )
                elif rec["status"] == "error":
                    msg += f" ({rec['error'][:200]})"
                print(f"[done] {arch} {shape} {mesh_kind}: {msg}", flush=True)


if __name__ == "__main__":
    main()
