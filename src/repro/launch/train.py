"""QAT training driver.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch bit-bert-base --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --devices 4 --mesh 2x2 --steps 100

``--smoke`` selects the reduced config (real weights on this CPU container);
full configs are for real clusters — their step functions are exactly what
the dry-run lowers.  ``--devices N`` requests N host placeholder devices
(set before jax import, hence the env dance at the top).
"""

import argparse
import os


def _parse_early():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )


_parse_early()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs import get_config, list_configs  # noqa: E402
from repro.configs.smoke import smoke_variant  # noqa: E402
from repro.data.pipeline import DataConfig, TokenPipeline  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import fault_tolerance as FT  # noqa: E402
from repro.runtime import train_loop as TL  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x2")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    data, model = (int(x) for x in args.mesh.split("x"))
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data, model)

    tcfg = TL.TrainConfig(
        optimizer=adamw.AdamWConfig(
            lr=args.lr, warmup_steps=max(args.steps // 10, 1), total_steps=args.steps
        ),
        accum_steps=args.accum,
    )
    pipe = TokenPipeline(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
            frontend_positions=cfg.encoder.n_positions if cfg.encoder else 0,
            frontend_dim=(cfg.encoder.d_input or cfg.d_model) if cfg.encoder else 0,
        )
    )
    shapes = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    if cfg.encoder is not None:
        shapes["frontend"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.encoder.n_positions, cfg.encoder.d_input or cfg.d_model),
            jnp.float32,
        )
    step = TL.make_train_step(cfg, tcfg, mesh, shapes)
    params, opt = TL.init_train_state(jax.random.PRNGKey(args.seed), cfg)

    manager = CheckpointManager(args.ckpt_dir or f"/tmp/repro-ckpt-{args.arch}", keep=2)
    runner = FT.TrainingRunner(
        step,
        pipe,
        manager,
        FT.RunnerConfig(
            total_steps=args.steps,
            checkpoint_every=args.ckpt_every,
            log_every=max(args.steps // 20, 1),
        ),
    )
    runner.install_signal_handlers()
    start, params, opt = runner.try_restore(params, opt)
    try:
        params, opt, hist = runner.run(params, opt, start)
    finally:
        runner.restore_signal_handlers()
    if hist:
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"[train] loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    print(f"[train] p50 step {runner.p50*1e3:.0f} ms, p99 {runner.p99*1e3:.0f} ms")


if __name__ == "__main__":
    main()
