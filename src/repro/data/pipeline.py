"""Deterministic, shardable, checkpointable synthetic token pipeline.

Production contract (what a 1000-node deployment needs from its data layer):

* **Determinism**: batch ``i`` is a pure function of (seed, i) — restart
  from a checkpointed cursor reproduces the exact stream (bit-identical
  resume is tested in tests/test_fault_tolerance.py).
* **Sharding**: each data-parallel shard draws its disjoint slice by
  (shard_index, num_shards); no coordination or filesystem state needed.
* **Checkpointability**: pipeline state is one integer cursor (+ seed) —
  stored inside every checkpoint.

The generator synthesizes a mixture of Zipf-distributed tokens with local
n-gram structure, so LM losses actually *decrease* during the example QAT
runs (pure-uniform streams cannot be learned).  Swapping in a real corpus
means re-implementing ``_batch_at`` only.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_order: int = 3
    frontend_positions: int = 0  # >0: also emit stub frontend embeddings
    frontend_dim: int = 0


@dataclasses.dataclass
class TokenPipeline:
    """Stateful cursor over the deterministic stream."""

    cfg: DataConfig
    shard_index: int = 0
    num_shards: int = 1
    cursor: int = 0

    def __post_init__(self):
        if self.cfg.global_batch % self.num_shards:
            raise ValueError(
                f"global_batch {self.cfg.global_batch} not divisible by "
                f"{self.num_shards} shards"
            )
        # fixed per-seed n-gram transition structure (tiny, regenerated
        # identically everywhere from the seed)
        rng = np.random.default_rng(self.cfg.seed)
        v = self.cfg.vocab_size
        self._base_probs = 1.0 / np.arange(1, v + 1) ** self.cfg.zipf_a
        self._base_probs /= self._base_probs.sum()
        self._shift = rng.integers(1, max(2, v - 1))

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.num_shards

    def _batch_at(self, step: int) -> dict:
        """Pure function of (seed, step, shard) -> local batch."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * 65_537 + self.shard_index
        )
        b = self.local_batch
        # Zipf draws with a deterministic n-gram echo: token[t] depends on
        # token[t-k] with probability ~0.5, giving learnable structure.
        toks = rng.choice(c.vocab_size, size=(b, c.seq_len), p=self._base_probs)
        echo = (np.roll(toks, c.ngram_order, axis=1) + self._shift) % c.vocab_size
        mask = rng.random((b, c.seq_len)) < 0.5
        toks = np.where(mask, echo, toks)
        toks[:, : c.ngram_order] = toks[:, : c.ngram_order] % c.vocab_size
        batch = {"tokens": toks.astype(np.int32)}
        if c.frontend_positions:
            batch["frontend"] = rng.standard_normal(
                (b, c.frontend_positions, c.frontend_dim), dtype=np.float32
            )
        return batch

    def next(self) -> dict:
        batch = self._batch_at(self.cursor)
        self.cursor += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()

    # ---- checkpoint integration ----
    def state(self) -> dict:
        return {"cursor": self.cursor, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        if state.get("seed", self.cfg.seed) != self.cfg.seed:
            raise ValueError("pipeline seed mismatch on restore")
        self.cursor = int(state["cursor"])

    def reshard(self, shard_index: int, num_shards: int) -> "TokenPipeline":
        """Elastic rescale: same stream, new shard geometry (cursor kept)."""
        return TokenPipeline(
            cfg=self.cfg,
            shard_index=shard_index,
            num_shards=num_shards,
            cursor=self.cursor,
        )
