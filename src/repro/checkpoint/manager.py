"""Checkpointing: atomic, keep-k, resharding-tolerant (no orbax offline).

Format: one directory per step —
    step_000123/
      manifest.msgpack[.zst] # treedef, shapes, dtypes, shard geometry, extras
                             # (.zst only when the optional zstandard codec
                             #  is installed; readers accept either)
      arrays.npz             # flattened leaves (this host's shards)
      _COMMITTED             # written last; readers ignore dirs without it

Durability contract (what survives a 1000-node failure):

* **Atomicity**: writes go to ``step_X.tmp-<nonce>`` and are renamed into
  place after ``_COMMITTED`` lands — a host dying mid-save can never corrupt
  a restore point (rename is atomic on POSIX).  Overwriting a committed step
  renames the old dir aside (``step_X.old-<nonce>``) first and removes it
  only after the new commit lands; a stranded aside is renamed back by
  recovery at manager construction, so no crash point loses the step.
* **Exotic dtypes**: ml_dtypes leaves (bfloat16, float8_*) are stored
  bit-cast to same-width uints (npz would degrade them to raw void bytes)
  and viewed back on restore — serving caches checkpoint losslessly.
* **Keep-k**: older committed steps are pruned after a successful commit,
  never before.
* **Elastic restore**: leaves are stored UNSHARDED from this single-host
  container (multihost note below); ``restore`` re-shards onto whatever mesh
  the new job brings up — tested save-on-4-devices / restore-on-2.
* **Data-pipeline state** and optimizer step ride inside the manifest, so a
  resumed run is bit-identical (tests/test_fault_tolerance.py).

Multihost: on a real cluster each host writes ``arrays-<proc>.npz`` with its
addressable shards and process 0 writes the manifest; the single-process
container exercises the same code path with proc=0.
"""

from __future__ import annotations

import os
import re
import shutil
import tempfile
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional codec: absent -> manifests are written uncompressed
    import zstandard
except ImportError:  # pragma: no cover - depends on container contents
    zstandard = None

__all__ = ["CheckpointManager"]

_MANIFEST_ZST = "manifest.msgpack.zst"
_MANIFEST_RAW = "manifest.msgpack"


def _write_manifest(dirname: str, manifest: dict) -> None:
    payload = msgpack.packb(manifest)
    if zstandard is not None:
        with open(os.path.join(dirname, _MANIFEST_ZST), "wb") as f:
            f.write(zstandard.ZstdCompressor().compress(payload))
    else:
        with open(os.path.join(dirname, _MANIFEST_RAW), "wb") as f:
            f.write(payload)


def _read_manifest(dirname: str) -> dict:
    """Read either codec, whichever the writing host had available."""
    zst_path = os.path.join(dirname, _MANIFEST_ZST)
    if os.path.exists(zst_path):
        if zstandard is None:
            raise RuntimeError(
                f"{zst_path} is zstd-compressed but the zstandard module is "
                "not installed (pip install zstandard)"
            )
        with open(zst_path, "rb") as f:
            return msgpack.unpackb(zstandard.ZstdDecompressor().decompress(f.read()))
    with open(os.path.join(dirname, _MANIFEST_RAW), "rb") as f:
        return msgpack.unpackb(f.read())

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


# npz silently degrades non-native dtypes (ml_dtypes: bfloat16, float8_*) to
# raw void bytes; such leaves are stored bit-cast to a same-width uint and
# viewed back on restore (serve caches are full of bf16 rows).
_BITCAST_BY_ITEMSIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._recover()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extras: Optional[dict] = None) -> str:
        """Atomically persist ``tree`` (+ JSON-able ``extras``) for ``step``.

        Overwriting an existing committed step never opens a loss window:
        the old directory is renamed ASIDE (``step_X.old-<nonce>``) before
        the new one is renamed into place, and removed only after the new
        commit lands.  A crash anywhere in between leaves either the final
        dir or the aside dir committed; :meth:`_recover` (run at manager
        construction) renames a stranded aside back into place.
        """
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp-", dir=self.directory)
        old = None
        try:
            paths, leaves, _ = _flatten_with_paths(tree)
            arrays = {}
            meta = []
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                arr = np.asarray(jax.device_get(leaf))
                entry = {"path": p, "dtype": str(arr.dtype), "shape": list(arr.shape)}
                if arr.dtype.kind == "V":  # ml_dtypes leaf: store bit-cast
                    store = arr.view(_BITCAST_BY_ITEMSIZE[arr.dtype.itemsize])
                    entry["stored_as"] = str(store.dtype)
                    arr = store
                arrays[f"a{i}"] = arr
                meta.append(entry)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "leaves": meta,
                "extras": extras or {},
                "time": time.time(),
                "proc": 0,
            }
            _write_manifest(tmp, manifest)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                # rename aside, never rmtree-then-rename: a crash between
                # those two would lose the only committed copy of this step
                old = final + ".old-" + os.path.basename(tmp).rsplit(".tmp-", 1)[1]
                os.rename(final, old)
            os.rename(tmp, final)
            if old is not None:
                shutil.rmtree(old, ignore_errors=True)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            # an in-process failure between the two renames: put the old
            # committed step back where readers look for it
            if old is not None and os.path.exists(old) and not os.path.exists(final):
                os.rename(old, final)
            raise
        self._prune()
        return final

    # ------------------------------------------------------------------
    def _recover(self) -> None:
        """Repair the overwrite crash window: a committed ``step_X.old-*``
        aside whose ``step_X`` is missing is renamed back into place (the
        process died between the two renames of an overwrite); asides whose
        final exists are leftovers of a crash after commit and are removed."""
        for name in os.listdir(self.directory):
            if ".old-" not in name:
                continue
            aside = os.path.join(self.directory, name)
            final = os.path.join(self.directory, name.split(".old-", 1)[0])
            if not _STEP_RE.match(os.path.basename(final)):
                continue
            if os.path.exists(os.path.join(final, "_COMMITTED")):
                shutil.rmtree(aside, ignore_errors=True)
            elif os.path.exists(os.path.join(aside, "_COMMITTED")):
                shutil.rmtree(final, ignore_errors=True)  # uncommitted husk
                os.rename(aside, final)
            else:
                shutil.rmtree(aside, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "_COMMITTED")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        like: Any = None,
        shardings: Any = None,
    ) -> Tuple[int, Any, dict]:
        """Load (step, tree, extras).

        ``like``: template pytree — structure/dtypes to restore into (the new
        job's params template).  ``shardings``: optional matching pytree of
        NamedSharding — leaves are placed directly onto the (possibly
        different) mesh: this IS the elastic-rescale path.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        manifest = _read_manifest(d)
        data = np.load(os.path.join(d, "arrays.npz"))
        arrays = [data[f"a{i}"] for i in range(len(manifest["leaves"]))]

        if like is None:
            raise ValueError("restore requires a template pytree (like=)")
        paths, leaves, treedef = _flatten_with_paths(like)
        by_path = {m["path"]: (m, a) for m, a in zip(manifest["leaves"], arrays)}
        out = []
        flat_shardings = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
        )
        for p, leaf, sh in zip(paths, leaves, flat_shardings):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p}")
            entry, arr = by_path[p]
            if "stored_as" in entry:  # bit-cast ml_dtypes leaf: view back
                arr = arr.view(np.dtype(entry["dtype"]))
            want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch at {p}: {arr.shape} vs {leaf.shape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return step, treedef.unflatten(out), manifest["extras"]

    # ------------------------------------------------------------------
    def _prune(self) -> None:
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.directory)
            if (m := _STEP_RE.match(name))
            and os.path.exists(os.path.join(self.directory, name, "_COMMITTED"))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
        # clean stale tmpdirs from crashed saves
        for name in os.listdir(self.directory):
            if ".tmp-" in name:
                full = os.path.join(self.directory, name)
                if time.time() - os.path.getmtime(full) > 3600:
                    shutil.rmtree(full, ignore_errors=True)
