"""Checkpointing: atomic, keep-k, resharding-tolerant (no orbax offline).

Format: one directory per step —
    step_000123/
      manifest.msgpack[.zst] # treedef, shapes, dtypes, shard geometry, extras
                             # (.zst only when the optional zstandard codec
                             #  is installed; readers accept either)
      arrays.npz             # flattened leaves (this host's shards)
      _COMMITTED             # written last; readers ignore dirs without it

Durability contract (what survives a 1000-node failure):

* **Atomicity**: writes go to ``step_X.tmp-<nonce>`` and are renamed into
  place after ``_COMMITTED`` lands — a host dying mid-save can never corrupt
  a restore point (rename is atomic on POSIX).
* **Keep-k**: older committed steps are pruned after a successful commit,
  never before.
* **Elastic restore**: leaves are stored UNSHARDED from this single-host
  container (multihost note below); ``restore`` re-shards onto whatever mesh
  the new job brings up — tested save-on-4-devices / restore-on-2.
* **Data-pipeline state** and optimizer step ride inside the manifest, so a
  resumed run is bit-identical (tests/test_fault_tolerance.py).

Multihost: on a real cluster each host writes ``arrays-<proc>.npz`` with its
addressable shards and process 0 writes the manifest; the single-process
container exercises the same code path with proc=0.
"""

from __future__ import annotations

import os
import re
import shutil
import tempfile
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional codec: absent -> manifests are written uncompressed
    import zstandard
except ImportError:  # pragma: no cover - depends on container contents
    zstandard = None

__all__ = ["CheckpointManager"]

_MANIFEST_ZST = "manifest.msgpack.zst"
_MANIFEST_RAW = "manifest.msgpack"


def _write_manifest(dirname: str, manifest: dict) -> None:
    payload = msgpack.packb(manifest)
    if zstandard is not None:
        with open(os.path.join(dirname, _MANIFEST_ZST), "wb") as f:
            f.write(zstandard.ZstdCompressor().compress(payload))
    else:
        with open(os.path.join(dirname, _MANIFEST_RAW), "wb") as f:
            f.write(payload)


def _read_manifest(dirname: str) -> dict:
    """Read either codec, whichever the writing host had available."""
    zst_path = os.path.join(dirname, _MANIFEST_ZST)
    if os.path.exists(zst_path):
        if zstandard is None:
            raise RuntimeError(
                f"{zst_path} is zstd-compressed but the zstandard module is "
                "not installed (pip install zstandard)"
            )
        with open(zst_path, "rb") as f:
            return msgpack.unpackb(zstandard.ZstdDecompressor().decompress(f.read()))
    with open(os.path.join(dirname, _MANIFEST_RAW), "rb") as f:
        return msgpack.unpackb(f.read())

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extras: Optional[dict] = None) -> str:
        """Atomically persist ``tree`` (+ JSON-able ``extras``) for ``step``."""
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp-", dir=self.directory)
        try:
            paths, leaves, _ = _flatten_with_paths(tree)
            arrays = {}
            meta = []
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                arr = np.asarray(jax.device_get(leaf))
                arrays[f"a{i}"] = arr
                meta.append({"path": p, "dtype": str(arr.dtype), "shape": list(arr.shape)})
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "leaves": meta,
                "extras": extras or {},
                "time": time.time(),
                "proc": 0,
            }
            _write_manifest(tmp, manifest)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._prune()
        return final

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "_COMMITTED")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        like: Any = None,
        shardings: Any = None,
    ) -> Tuple[int, Any, dict]:
        """Load (step, tree, extras).

        ``like``: template pytree — structure/dtypes to restore into (the new
        job's params template).  ``shardings``: optional matching pytree of
        NamedSharding — leaves are placed directly onto the (possibly
        different) mesh: this IS the elastic-rescale path.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        manifest = _read_manifest(d)
        data = np.load(os.path.join(d, "arrays.npz"))
        arrays = [data[f"a{i}"] for i in range(len(manifest["leaves"]))]

        if like is None:
            raise ValueError("restore requires a template pytree (like=)")
        paths, leaves, treedef = _flatten_with_paths(like)
        by_path = {m["path"]: a for m, a in zip(manifest["leaves"], arrays)}
        out = []
        flat_shardings = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
        )
        for p, leaf, sh in zip(paths, leaves, flat_shardings):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p}")
            arr = by_path[p]
            want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch at {p}: {arr.shape} vs {leaf.shape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return step, treedef.unflatten(out), manifest["extras"]

    # ------------------------------------------------------------------
    def _prune(self) -> None:
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.directory)
            if (m := _STEP_RE.match(name))
            and os.path.exists(os.path.join(self.directory, name, "_COMMITTED"))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
        # clean stale tmpdirs from crashed saves
        for name in os.listdir(self.directory):
            if ".tmp-" in name:
                full = os.path.join(self.directory, name)
                if time.time() - os.path.getmtime(full) > 3600:
                    shutil.rmtree(full, ignore_errors=True)
