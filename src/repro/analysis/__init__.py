"""Static analysis for the quantized engine: jaxpr invariant verifier
(Pass 1) + AST lint (Pass 2).  ``python -m repro.analysis`` runs both; see
docs/static-analysis.md for the rule catalog and allowlist format."""

from repro.analysis.findings import AllowEntry, Allowlist, Finding
from repro.analysis.lint import RULES, lint_file, lint_paths, lint_source
from repro.analysis.verifier import (
    check_cache_contract,
    check_function,
    verify_arch,
    verify_archs,
    verify_backends,
)

__all__ = [
    "AllowEntry",
    "Allowlist",
    "Finding",
    "RULES",
    "lint_source",
    "lint_file",
    "lint_paths",
    "check_function",
    "check_cache_contract",
    "verify_arch",
    "verify_archs",
    "verify_backends",
]
