"""CLI: ``python -m repro.analysis`` — run both passes, exit 1 on findings.

Default run = AST lint over ``src/`` + jaxpr verifier over every registered
QMM backend and every assigned model-zoo arch at smoke sizes, filtered
through ``analysis/allowlist.toml``.  Any surviving finding (or a stale
allowlist entry) exits nonzero, so the CI cell fails on anything new.

Useful subsets:
  --skip-verifier / --skip-lint     run one pass only
  --src PATH                        lint a different tree or a single file
  --backends mxu,pallas             restrict the backend sweep
  --archs gpt2,whisper-small       restrict the arch sweep
  --format json                     machine-readable findings
  --self-test                       prove the checker still detects seeded
                                    known-bad fixtures (used by CI)
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import findings as F
from repro.analysis import lint

_REPO_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "..")
)


def _parse_args(argv):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static quantization-invariant verifier + JAX/Pallas lint",
    )
    p.add_argument(
        "--src",
        default=os.path.join(_REPO_ROOT, "src"),
        help="tree (or single file) to lint [default: repo src/]",
    )
    p.add_argument(
        "--root",
        default=_REPO_ROOT,
        help="root that reported paths are made relative to",
    )
    p.add_argument(
        "--allowlist",
        default=os.path.join(_REPO_ROOT, "analysis", "allowlist.toml"),
        help="allowlist TOML ('' disables) [default: analysis/allowlist.toml]",
    )
    p.add_argument("--skip-lint", action="store_true", help="skip the AST pass")
    p.add_argument(
        "--skip-verifier", action="store_true", help="skip the jaxpr pass"
    )
    p.add_argument(
        "--backends",
        default="",
        help="comma-separated backend subset for the QMM sweep",
    )
    p.add_argument(
        "--archs",
        default="",
        help="comma-separated model-zoo arch subset for the serving sweep",
    )
    p.add_argument(
        "--rules", default="", help="comma-separated lint rule subset (RNG001,...)"
    )
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument(
        "--fail-on-findings",
        action="store_true",
        help="exit 1 when findings remain (already the default; kept for "
        "explicit CI invocations)",
    )
    p.add_argument(
        "--list-rules", action="store_true", help="print the lint rule catalog"
    )
    p.add_argument(
        "--self-test",
        action="store_true",
        help="run the passes against the seeded known-bad fixtures and fail "
        "unless every expected finding class is detected",
    )
    return p.parse_args(argv)


def _collect(args):
    all_findings = []
    if not args.skip_lint:
        rules = [r for r in args.rules.split(",") if r] or None
        all_findings.extend(lint.lint_paths(args.src, root=args.root, rules=rules))
    if not args.skip_verifier:
        from repro.analysis import verifier

        backends = tuple(b for b in args.backends.split(",") if b) or None
        archs = tuple(a for a in args.archs.split(",") if a) or None
        all_findings.extend(verifier.verify_backends(backends))
        all_findings.extend(verifier.verify_archs(archs))
        if backends is None and archs is None:
            # full sweep: also trace the bitwise-attention engagement (the
            # scores backend family has its own calling convention, and
            # bit-bert-base is encoder-family so the arch sweep skips it)
            all_findings.extend(verifier.verify_binary_attention())
    return all_findings


def _self_test(args) -> int:
    """The checker checking itself: the seeded fixtures MUST trip it."""
    from repro.analysis import selftest

    failures = selftest.run(_REPO_ROOT)
    for msg in failures:
        print(f"self-test FAIL: {msg}")
    if failures:
        return 1
    print("self-test OK: all seeded fixtures detected")
    return 0


def main(argv=None) -> int:
    args = _parse_args(sys.argv[1:] if argv is None else argv)

    if args.list_rules:
        for rid, meta in lint.RULES.items():
            print(f"{rid}  {meta['title']}")
        return 0

    if args.self_test:
        return _self_test(args)

    found = _collect(args)

    stale = []
    suppressed = []
    if args.allowlist and os.path.exists(args.allowlist):
        allow = F.Allowlist.load(args.allowlist)
        found, suppressed = allow.filter(found)
        # staleness is only meaningful on a full run: a subset run (one pass,
        # one rule, one arch...) legitimately produces no hits for most entries
        full_run = not (
            args.skip_lint
            or args.skip_verifier
            or args.rules
            or args.backends
            or args.archs
        )
        if full_run:
            stale = allow.stale_entries()

    if args.format == "json":
        print(F.render_json(found, suppressed))
    else:
        print(F.render_text(found, suppressed, stale))

    # findings fail by default; a stale allowlist entry is also a failure
    # (it means the justified hit it documented no longer exists).
    return 1 if (found or stale) else 0


if __name__ == "__main__":
    raise SystemExit(main())
