"""Pass 2 — AST lint over library code for JAX/Pallas pitfalls.

Purely syntactic (``ast`` module, no imports of the scanned code), so it can
run on any Python source — including the known-bad fixture snippets the test
suite seeds.  Each rule yields :class:`~repro.analysis.findings.Finding`
objects; the CLI filters them through the checked-in allowlist.

Rule catalog (docs/static-analysis.md has the full rationale):

  RNG001  global NumPy RNG call (``np.random.seed/rand/...``) in library code
  RNG002  ``jax.random.PRNGKey(<literal>)`` outside ``jax.eval_shape``
  TIME001 wall-clock call inside a jit-decorated function (baked at trace)
  TRACE001 Python ``if``/``while`` on a traced-value reduction (``jnp.any``...)
  DTYPE001 hardcoded ``jnp.bfloat16``/``jnp.float16`` literal (serve/cache
           dtypes must derive from the initialized leaf; the PR 6 drift bug)
  MUT001  mutable default argument
"""

from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Sequence

from repro.analysis.findings import Finding

__all__ = ["RULES", "lint_source", "lint_file", "lint_paths"]


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------


def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._lint_parent = node  # type: ignore[attr-defined]


def _ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = getattr(node, "_lint_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_lint_parent", None)


def _symbol(node: ast.AST) -> str:
    """Dotted chain of enclosing function names ("outer.inner"), or
    "<module>" at module level.  Line-number-free, so allowlist entries
    survive unrelated edits."""
    names = [
        a.name
        for a in _ancestors(node)
        if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        names.insert(0, node.name)
    return ".".join(reversed(names)) or "<module>"


def _chain(node: ast.AST) -> str:
    """Dotted name of an attribute/name expression ("jax.random.PRNGKey"),
    "" when the expression is not a plain chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _finding(rule: str, node: ast.AST, path: str, message: str, hint: str) -> Finding:
    return Finding(
        rule=rule,
        path=path,
        line=getattr(node, "lineno", 0),
        symbol=_symbol(node),
        message=message,
        hint=hint,
    )


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

_GLOBAL_RNG_FNS = {
    "seed",
    "rand",
    "randn",
    "randint",
    "random",
    "normal",
    "uniform",
    "choice",
    "permutation",
    "shuffle",
    "standard_normal",
}


def _rule_rng001(tree: ast.AST, path: str) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _chain(node.func)
        parts = chain.split(".")
        if (
            len(parts) == 3
            and parts[0] in ("np", "numpy")
            and parts[1] == "random"
            and parts[2] in _GLOBAL_RNG_FNS
        ):
            yield _finding(
                "RNG001",
                node,
                path,
                f"global NumPy RNG call {chain}() — hidden process-wide state",
                "use an explicit np.random.default_rng(seed) Generator",
            )


def _rule_rng002(tree: ast.AST, path: str) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _chain(node.func)
        if not (chain == "PRNGKey" or chain.endswith(".PRNGKey")):
            continue
        if not (node.args and isinstance(node.args[0], ast.Constant)):
            continue  # seed threaded from the caller — fine
        # shape-only traces never consume the key's value
        in_eval_shape = any(
            isinstance(a, ast.Call) and _chain(a.func).endswith("eval_shape")
            for a in _ancestors(node)
        )
        if in_eval_shape:
            continue
        yield _finding(
            "RNG002",
            node,
            path,
            f"PRNGKey with hardcoded seed {ast.unparse(node.args[0])} in library code",
            "thread the key (or seed) in from the caller; "
            "jax.eval_shape traces are exempt (value never consumed)",
        )


_WALLCLOCK = {"time.time", "time.perf_counter", "time.monotonic"}


def _is_jitted(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        for sub in ast.walk(dec):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                chain = _chain(sub)
                if chain == "jit" or chain.endswith(".jit"):
                    return True
    return False


def _rule_time001(tree: ast.AST, path: str) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or _chain(node.func) not in _WALLCLOCK:
            continue
        jitted = any(
            isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef)) and _is_jitted(a)
            for a in _ancestors(node)
        )
        if jitted:
            yield _finding(
                "TIME001",
                node,
                path,
                f"{_chain(node.func)}() inside a jit-decorated function — "
                "evaluated once at trace time, constant thereafter",
                "time outside the traced function (callers own the clock)",
            )


_TRACED_REDUCERS = {
    "any",
    "all",
    "sum",
    "max",
    "min",
    "mean",
    "isnan",
    "isinf",
    "isfinite",
    "count_nonzero",
    "array_equal",
    "allclose",
}


def _rule_trace001(tree: ast.AST, path: str) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
        elif isinstance(node, ast.IfExp):
            test = node.test
        else:
            continue
        for sub in ast.walk(test):
            if not isinstance(sub, ast.Call):
                continue
            chain = _chain(sub.func)
            parts = chain.split(".")
            if (
                len(parts) >= 2
                and parts[0] in ("jnp", "jax")
                and parts[-1] in _TRACED_REDUCERS
            ):
                yield _finding(
                    "TRACE001",
                    node,
                    path,
                    f"Python branch on traced value {chain}(...) — "
                    "raises ConcretizationTypeError under jit, or silently "
                    "bakes the traced branch",
                    "use jnp.where / jax.lax.cond, or hoist the check out of "
                    "traced code",
                )
                break  # one finding per branch statement


_DTYPE_LITERALS = {
    "jnp.bfloat16",
    "jnp.float16",
    "jax.numpy.bfloat16",
    "jax.numpy.float16",
}


def _rule_dtype001(tree: ast.AST, path: str) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and _chain(node) in _DTYPE_LITERALS:
            yield _finding(
                "DTYPE001",
                node,
                path,
                f"hardcoded low-precision dtype literal {_chain(node)}",
                "derive the dtype from the tensor it must match "
                "(cache[...].dtype / x.dtype) — a literal here is how the "
                "PR 6 cache-dtype drift happened; allowlist declaration "
                "sites and config gates",
            )


def _rule_mut001(tree: ast.AST, path: str) -> Iterator[Finding]:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and _chain(d.func) in ("list", "dict", "set")
            )
            if mutable:
                yield _finding(
                    "MUT001",
                    d,
                    path,
                    f"mutable default argument in {node.name}()",
                    "default to None and construct inside the function body",
                )


RULES: Dict[str, dict] = {
    "RNG001": {
        "title": "global NumPy RNG in library code",
        "fn": _rule_rng001,
    },
    "RNG002": {
        "title": "PRNGKey with hardcoded seed (eval_shape exempt)",
        "fn": _rule_rng002,
    },
    "TIME001": {
        "title": "wall-clock read inside a jitted function",
        "fn": _rule_time001,
    },
    "TRACE001": {
        "title": "Python branch on a traced-value reduction",
        "fn": _rule_trace001,
    },
    "DTYPE001": {
        "title": "hardcoded bf16/f16 dtype literal",
        "fn": _rule_dtype001,
    },
    "MUT001": {
        "title": "mutable default argument",
        "fn": _rule_mut001,
    },
}


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def lint_source(
    source: str, path: str, rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Lint one source string; ``path`` is the repo-relative name reported."""
    tree = ast.parse(source, filename=path)
    _attach_parents(tree)
    out: List[Finding] = []
    for rid in rules or RULES:
        out.extend(RULES[rid]["fn"](tree, path))
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def lint_file(
    path: str, root: str = ".", rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), rel, rules)


def lint_paths(
    src: str, root: str = ".", rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Lint every ``*.py`` under ``src`` (a file path is also accepted)."""
    if os.path.isfile(src):
        return lint_file(src, root, rules)
    out: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(src):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for name in sorted(filenames):
            if name.endswith(".py"):
                out.extend(lint_file(os.path.join(dirpath, name), root, rules))
    return out
