"""Self-test: prove the checker still detects the seeded known-bad fixtures.

A static checker that silently stops finding things is worse than no
checker — CI runs ``python -m repro.analysis --self-test`` so any refactor
of the lint rules or the taint walker that loses detection power fails the
build, not just the unit tests.
"""

from __future__ import annotations

import importlib.util
import os
from typing import List

import jax
import jax.numpy as jnp

from repro.analysis import lint, verifier

#: every lint rule must fire on lint_bad.py
_EXPECT_LINT = ("RNG001", "RNG002", "TIME001", "TRACE001", "DTYPE001", "MUT001")


def load_fixture_module(path: str):
    """Import a fixture file by path without touching sys.path."""
    spec = importlib.util.spec_from_file_location("analysis_fixture", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def run(repo_root: str) -> List[str]:
    """Returns failure messages; empty list == all seeded bugs detected."""
    fixtures = os.path.join(repo_root, "analysis", "fixtures")
    failures: List[str] = []

    # ---- lint pass detects every rule on the bad fixture ----
    bad = lint.lint_file(os.path.join(fixtures, "lint_bad.py"), root=repo_root)
    fired = {f.rule for f in bad}
    for rule in _EXPECT_LINT:
        if rule not in fired:
            failures.append(f"lint rule {rule} did not fire on lint_bad.py")

    # ---- and stays quiet on the good fixture ----
    good = lint.lint_file(os.path.join(fixtures, "lint_good.py"), root=repo_root)
    for f in good:
        failures.append(f"false positive on lint_good.py: {f.rule} at line {f.line}")

    # ---- jaxpr verifier detects every seeded kernel bug ----
    K = load_fixture_module(os.path.join(fixtures, "bad_kernel.py"))
    u32 = jax.ShapeDtypeStruct((8, 2), jnp.uint32)
    i8 = jnp.int8
    cases = [
        (
            "INV-PACKED-FLOAT",
            lambda: verifier.check_function(K.leak_packed_to_float, u32),
        ),
        (
            "INV-ACCUM-LOWFP",
            lambda: verifier.check_function(K.accumulate_in_bf16, u32, u32),
        ),
        (
            # same rule at the trusted kernel boundary: a pallas_call fed
            # packed planes may exit int (counts) or f32 (fused epilogue),
            # never bf16/f16
            "INV-ACCUM-LOWFP",
            lambda: verifier.check_function(K.fused_kernel_lowfp, u32, u32),
        ),
        (
            # the attention-shaped variant: rank-4 packed planes, the score
            # accumulation rounded through bfloat16
            "INV-ACCUM-LOWFP",
            lambda: verifier.check_function(
                K.binary_attn_lowfp,
                jax.ShapeDtypeStruct((1, 2, 4, 2), jnp.uint32),
                jax.ShapeDtypeStruct((1, 2, 3, 2), jnp.uint32),
            ),
        ),
        (
            "INV-INT-DOT",
            lambda: verifier.check_function(
                K.int_dot_low_precision,
                jax.ShapeDtypeStruct((4, 8), i8),
                jax.ShapeDtypeStruct((8, 4), i8),
            ),
        ),
        (
            "INV-CACHE-DTYPE",
            lambda: verifier.check_cache_contract(
                lambda: K.init_cache(2, 8, 4),
                K.drifting_step,
                jax.ShapeDtypeStruct((2, 4), jnp.float32),
            ),
        ),
        (
            "INV-CACHE-SHAPE",
            lambda: verifier.check_cache_contract(
                lambda: K.init_cache(2, 8, 4),
                K.growing_step,
                jax.ShapeDtypeStruct((2, 4), jnp.float32),
            ),
        ),
    ]
    for rule, thunk in cases:
        got = {f.rule for f in thunk()}
        if rule not in got:
            failures.append(
                f"verifier did not flag {rule} on the bad_kernel fixture "
                f"(got: {sorted(got) or 'nothing'})"
            )

    # ---- and the real fused kernel's jaxpr passes the taint rules ----
    # (its pallas_call consumes packed planes and exits f32 — the legal
    # fused-epilogue exit; INV-PACKED-FLOAT / INV-ACCUM-LOWFP stay quiet)
    for f in verifier.verify_backends(("fused",)):
        failures.append(
            f"fused kernel jaxpr not clean: {f.rule} {f.message}"
        )

    # ---- same for the real bitwise-attention cores: every scores-family
    # backend consumes packed planes and exits int32 counts, cleanly ----
    import functools

    from repro.core import backend_registry

    q = jax.ShapeDtypeStruct((1, 4, 6, 2), jnp.uint32)
    k = jax.ShapeDtypeStruct((1, 2, 5, 2), jnp.uint32)
    for name in backend_registry.backend_names(family="scores"):
        spec = backend_registry.get_backend(name)
        for f in verifier.check_function(
            functools.partial(spec.run_scores, dh=48), q, k, name=f"scores:{name}"
        ):
            failures.append(
                f"scores core {name} jaxpr not clean: {f.rule} {f.message}"
            )
    return failures
