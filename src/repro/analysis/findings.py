"""Finding / allowlist plumbing shared by the lint and verifier passes.

A :class:`Finding` is one rule violation: a stable rule id, the file (or
trace) it was found in, a line (0 for jaxpr-level findings, which have no
source line), the enclosing symbol, a message, and a fix hint.

``analysis/allowlist.toml`` (repo root) suppresses *justified* hits so CI
fails only on new ones.  Entries match on ``rule`` + ``file`` (fnmatch) +
``symbol`` (fnmatch) — never on line numbers, which churn with every edit —
and must carry a non-empty ``reason``.  Entries that match nothing are
reported as stale so the allowlist cannot silently rot.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Dict, List, Sequence, Tuple

try:  # py311+
    import tomllib as _toml
except ImportError:  # the container ships tomli
    import tomli as _toml  # type: ignore[no-redef]

__all__ = ["Finding", "AllowEntry", "Allowlist", "render_text", "render_json"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (lint or jaxpr-invariant)."""

    rule: str  # "RNG001" | "INV-PACKED-FLOAT" | ...
    path: str  # repo-relative file path, or "jaxpr:<trace>" for the verifier
    line: int  # 1-based source line; 0 for jaxpr findings
    symbol: str  # dotted enclosing function(s), or the trace name
    message: str
    hint: str = ""

    def location(self) -> str:
        if self.line:
            return f"{self.path}:{self.line}"
        return self.path

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    rule: str
    file: str  # fnmatch pattern over Finding.path
    symbol: str  # fnmatch pattern over Finding.symbol
    reason: str

    def matches(self, f: Finding) -> bool:
        return (
            f.rule == self.rule
            and fnmatch.fnmatchcase(f.path, self.file)
            and fnmatch.fnmatchcase(f.symbol, self.symbol)
        )


class Allowlist:
    """Checked-in suppressions (``[[allow]]`` entries in a TOML file)."""

    def __init__(self, entries: Sequence[AllowEntry] = ()):
        self.entries: Tuple[AllowEntry, ...] = tuple(entries)
        self._hits: Dict[AllowEntry, int] = {e: 0 for e in self.entries}

    @classmethod
    def load(cls, path: str) -> "Allowlist":
        with open(path, "rb") as f:
            data = _toml.load(f)
        raw = data.get("allow", [])
        if not isinstance(raw, list):
            raise ValueError(f"{path}: 'allow' must be an array of tables")
        entries = []
        for i, item in enumerate(raw):
            missing = [
                k for k in ("rule", "file", "symbol", "reason") if not item.get(k)
            ]
            if missing:
                raise ValueError(
                    f"{path}: [[allow]] entry {i} missing/empty field(s): {missing}"
                )
            entries.append(
                AllowEntry(
                    rule=item["rule"],
                    file=item["file"],
                    symbol=item["symbol"],
                    reason=item["reason"],
                )
            )
        return cls(entries)

    def filter(self, findings: Sequence[Finding]) -> Tuple[List[Finding], List[Finding]]:
        """Split findings into (kept, suppressed), recording entry hit counts."""
        kept: List[Finding] = []
        suppressed: List[Finding] = []
        for f in findings:
            entry = next((e for e in self.entries if e.matches(f)), None)
            if entry is None:
                kept.append(f)
            else:
                self._hits[entry] += 1
                suppressed.append(f)
        return kept, suppressed

    def stale_entries(self) -> List[AllowEntry]:
        """Entries that matched nothing across every ``filter`` call so far."""
        return [e for e, n in self._hits.items() if n == 0]


def render_text(
    findings: Sequence[Finding],
    suppressed: Sequence[Finding] = (),
    stale: Sequence[AllowEntry] = (),
) -> str:
    lines: List[str] = []
    for f in findings:
        sym = f" ({f.symbol})" if f.symbol else ""
        lines.append(f"{f.rule} {f.location()}{sym}: {f.message}")
        if f.hint:
            lines.append(f"    hint: {f.hint}")
    if suppressed:
        lines.append(f"-- {len(suppressed)} finding(s) suppressed by allowlist")
    for e in stale:
        lines.append(
            f"-- stale allowlist entry (matched nothing): "
            f"rule={e.rule} file={e.file} symbol={e.symbol}"
        )
    lines.append(
        f"{len(findings)} finding(s)"
        + (f", {len(suppressed)} suppressed" if suppressed else "")
    )
    return "\n".join(lines)


def render_json(
    findings: Sequence[Finding], suppressed: Sequence[Finding] = ()
) -> str:
    return json.dumps(
        {
            "findings": [f.to_dict() for f in findings],
            "suppressed": [f.to_dict() for f in suppressed],
        },
        indent=2,
    )
