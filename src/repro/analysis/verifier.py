"""Pass 1 — jaxpr invariant verifier for the quantized datapath.

The paper's efficiency claims rest on invariants the type system never sees
(§III-A computation flow abstraction): packed bit-planes may only meet
integer/bitwise ops until a popcount consumes them, accumulation stays
integer across bit significance, cache slots keep their initialized dtypes,
and every serve-mode QMM site quantizes to the precision its QuantConfig
declares.  This module traces real code to jaxprs (via ShapeDtypeStruct
inputs — no FLOPs, no RAM) and walks them enforcing:

  INV-PACKED-FLOAT  a packed-bit-plane value (uint32 words from
                    core/packing.py) reaches a floating-point primitive
  INV-ACCUM-LOWFP   a popcount/kernel accumulator is converted to
                    bf16/f16 (f32/f64 epilogue casts are the legal exit)
  INV-INT-DOT       dot_general over integer operands accumulates in
                    anything but i32/i64/u32/u64
  INV-CACHE-DTYPE   a prefill/decode output cache leaf differs in dtype
                    from the ``init_cache`` leaf (the PR 6 drift class)
  INV-CACHE-SHAPE   ... or in shape
  INV-CACHE-STRUCT  ... or the cache pytree structure itself changed
  INV-SITE-NAME     a serve-mode qlinear QMM ran at an unnamed site
                    (unnameable sites cannot get backend overrides)
  INV-SITE-BITS     a site quantized to a precision other than the one
                    its QuantConfig declares
  INV-SITE-MANTISSA a site produced a mantissa dtype violating the
                    quantizer contract (uint8 for <=8 bits, int8 after
                    re-centering, int32 above)

Taint semantics: uint32 trace inputs and outputs of the jitted pack
helpers (``pack_bits`` / ``pack_bitplanes`` / ``to_bitplanes``) carry the
"packed" taint; ``unpack_bits`` / ``from_bitplanes`` launder it;
``population_count`` and ``pallas_call`` (trusted kernel boundary — kernel
internals are covered by the parity tests against ``kernels/ref.py``)
consume "packed" and emit the "counts" taint on integer outputs.
Converting counts to f32/f64 is the legal epilogue exit — including a
Pallas kernel that applies the affine epilogue on-chip and returns f32
directly (``kernels/fused_qmm.py``); a kernel or cast producing bf16/f16
from packed/counts operands is INV-ACCUM-LOWFP.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.analysis.findings import Finding
from repro.core import backend_registry, packing
from repro.core import qmm as QE
from repro.core import site_log
from repro.core.quantization import QuantTensor

__all__ = [
    "check_function",
    "check_cache_contract",
    "verify_backends",
    "verify_binary_attention",
    "verify_arch",
    "verify_archs",
    "DEFAULT_ARCHS",
]

TAINT_PACKED = "packed"
TAINT_COUNTS = "counts"

#: jitted helpers whose outputs ARE packed bit-planes (don't recurse).
PACK_NAMES = frozenset({"pack_bits", "pack_bitplanes", "to_bitplanes"})
#: jitted helpers that consume packed words and return logical mantissas.
UNPACK_NAMES = frozenset({"unpack_bits", "from_bitplanes"})

_INT_ACCUM_DTYPES = {
    jnp.dtype(jnp.int32),
    jnp.dtype(jnp.int64),
    jnp.dtype(jnp.uint32),
    jnp.dtype(jnp.uint64),
}
# referenced as *data* (the dtypes the rule is about), hence string names
_LOWFP_DTYPES = {jnp.dtype("bfloat16"), jnp.dtype("float16")}


# ---------------------------------------------------------------------------
# taint walker
# ---------------------------------------------------------------------------


def _dtype(var) -> Optional[jnp.dtype]:
    aval = getattr(var, "aval", None)
    return jnp.dtype(aval.dtype) if getattr(aval, "dtype", None) is not None else None


def _is_float(var) -> bool:
    d = _dtype(var)
    return d is not None and jnp.issubdtype(d, jnp.floating)


def _is_int(var) -> bool:
    d = _dtype(var)
    return d is not None and jnp.issubdtype(d, jnp.integer)


class _TaintWalk:
    """One taint-propagation walk over a (closed) jaxpr tree."""

    def __init__(self, trace_name: str):
        self.trace_name = trace_name
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, str, str]] = set()  # dedup per trace

    # -- findings ----------------------------------------------------------

    def _violate(self, rule: str, eqn, message: str, hint: str) -> None:
        key = (rule, eqn.primitive.name, message)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(
            Finding(
                rule=rule,
                path=f"jaxpr:{self.trace_name}",
                line=0,
                symbol=self.trace_name,
                message=f"[{eqn.primitive.name}] {message}",
                hint=hint,
            )
        )

    # -- walk --------------------------------------------------------------

    def walk(self, jaxpr: jcore.Jaxpr, in_taints: Sequence[Set[str]]) -> List[Set[str]]:
        env: Dict[object, Set[str]] = {}

        def read(atom) -> Set[str]:
            if isinstance(atom, jcore.Literal):
                return set()
            return env.get(atom, set())

        def write(var, taints: Set[str]) -> None:
            if taints:
                env[var] = set(taints)

        n = min(len(jaxpr.invars), len(in_taints))
        for var, t in zip(jaxpr.invars[:n], in_taints[:n]):
            write(var, t)

        for eqn in jaxpr.eqns:
            self._eqn(eqn, read, write)
        return [read(v) for v in jaxpr.outvars]

    def _sub_jaxpr(self, obj) -> Optional[jcore.Jaxpr]:
        if isinstance(obj, jcore.Jaxpr):
            return obj
        inner = getattr(obj, "jaxpr", None)  # ClosedJaxpr
        return inner if isinstance(inner, jcore.Jaxpr) else None

    def _eqn(self, eqn, read, write) -> None:
        prim = eqn.primitive.name
        in_taints = [read(a) for a in eqn.invars]
        joined: Set[str] = set().union(*in_taints) if in_taints else set()

        if prim == "pjit":
            name = eqn.params.get("name", "")
            if name in PACK_NAMES:
                for v in eqn.outvars:
                    write(v, {TAINT_PACKED})
                return
            if name in UNPACK_NAMES:
                return  # logical mantissas come out clean
            inner = self._sub_jaxpr(eqn.params.get("jaxpr"))
            if inner is not None:
                outs = self.walk(inner, in_taints)
                for v, t in zip(eqn.outvars, outs):
                    write(v, t)
                return

        elif prim == "scan":
            inner = self._sub_jaxpr(eqn.params.get("jaxpr"))
            if inner is not None:
                # invars = consts + carry + xs, outvars = carry + ys: 1:1
                outs = self.walk(inner, in_taints)
                for v, t in zip(eqn.outvars, outs):
                    write(v, t)
                return

        elif prim in ("cond", "switch"):
            branches = eqn.params.get("branches", ())
            merged: Optional[List[Set[str]]] = None
            for br in branches:
                inner = self._sub_jaxpr(br)
                if inner is None:
                    merged = None
                    break
                outs = self.walk(inner, in_taints[1:])  # invars[0] = index
                if merged is None:
                    merged = [set(t) for t in outs]
                else:
                    merged = [a | b for a, b in zip(merged, outs)]
            if merged is not None:
                for v, t in zip(eqn.outvars, merged):
                    write(v, t)
                return

        elif prim == "while":
            inner = self._sub_jaxpr(eqn.params.get("body_jaxpr"))
            if inner is not None:
                cn = eqn.params.get("cond_nconsts", 0)
                outs = self.walk(inner, in_taints[cn:])
                for v, t in zip(eqn.outvars, outs):
                    write(v, t)
                return

        elif prim.startswith("custom_jvp_call") or prim.startswith("custom_vjp_call"):
            inner = self._sub_jaxpr(
                eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
            )
            if inner is not None:
                outs = self.walk(inner, in_taints)
                for v, t in zip(eqn.outvars, outs):
                    write(v, t)
                return

        elif prim in ("remat", "remat2", "checkpoint"):
            inner = self._sub_jaxpr(eqn.params.get("jaxpr"))
            if inner is not None:
                outs = self.walk(inner, in_taints)
                for v, t in zip(eqn.outvars, outs):
                    write(v, t)
                return

        elif prim == "population_count":
            # the ONE legal consumer of packed words on the float side of the
            # engine: emits per-word set-bit counts (integer accumulators).
            for v in eqn.outvars:
                write(v, {TAINT_COUNTS})
            return

        elif prim == "pallas_call":
            # Trusted kernel boundary: internals are covered by the parity
            # tests against kernels/ref.py.  A kernel fed packed/counted
            # operands may exit in two legal ways: an integer accumulator
            # (staged kernels; tagged "counts"), or f32/f64 — the fused
            # kernel's on-chip affine epilogue.  bf16/f16 output would mean
            # the popcount accumulation was finished in a low-precision
            # float, losing exactness.
            if joined & {TAINT_PACKED, TAINT_COUNTS}:
                lowfp = [
                    v for v in eqn.outvars if _dtype(v) in _LOWFP_DTYPES
                ]
                if lowfp:
                    self._violate(
                        "INV-ACCUM-LOWFP",
                        eqn,
                        "packed/accumulator operands feed a Pallas kernel "
                        "with low-precision float output "
                        f"{[str(_dtype(v)) for v in lowfp]}",
                        "kernels must return integer accumulators or finish "
                        "the epilogue in f32 (the fused-kernel exit) — "
                        "bf16/f16 loses popcount exactness",
                    )
                for v in eqn.outvars:
                    if _is_int(v):
                        write(v, {TAINT_COUNTS})
            return

        if prim == "dot_general":
            var_ins = [a for a in eqn.invars if not isinstance(a, jcore.Literal)]
            if var_ins and all(_is_int(a) for a in var_ins):
                bad = [
                    v for v in eqn.outvars if _dtype(v) not in _INT_ACCUM_DTYPES
                ]
                if bad:
                    self._violate(
                        "INV-INT-DOT",
                        eqn,
                        "integer-operand dot_general accumulates in "
                        f"{[str(_dtype(v)) for v in bad]}",
                        "pass preferred_element_type=jnp.int32 — integer QMM "
                        "accumulation must stay exact (paper §III-A)",
                    )

        self._generic(eqn, joined, write)

    def _generic(self, eqn, joined: Set[str], write) -> None:
        """Default propagation: packed/counts flow through integer ops;
        floating outputs are either violations (packed; counts->bf16/f16)
        or the legal f32/f64 epilogue exit (counts); bool outputs
        (comparisons, masks) drop taint."""
        if not joined:
            return
        float_outs = [v for v in eqn.outvars if _is_float(v)]
        if TAINT_PACKED in joined:
            if float_outs:
                self._violate(
                    "INV-PACKED-FLOAT",
                    eqn,
                    "packed bit-plane words reach a floating-point value "
                    f"({[str(_dtype(v)) for v in float_outs]})",
                    "packed uint32 words are storage, not numbers: unpack "
                    "(core.packing.unpack_bits) or popcount before any "
                    "float math",
                )
            else:
                for v in eqn.outvars:
                    if _is_int(v):
                        write(v, {TAINT_PACKED})
        if TAINT_COUNTS in joined:
            lowfp = [v for v in float_outs if _dtype(v) in _LOWFP_DTYPES]
            if lowfp:
                self._violate(
                    "INV-ACCUM-LOWFP",
                    eqn,
                    "integer accumulator converted to "
                    f"{[str(_dtype(v)) for v in lowfp]} — low-precision float "
                    "accumulation loses popcount exactness",
                    "keep accumulators int32; cast to f32 (not bf16/f16) in "
                    "the affine epilogue",
                )
            for v in eqn.outvars:
                if _is_int(v):
                    write(v, {TAINT_COUNTS})


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _seed_taints(jaxpr: jcore.Jaxpr, packed_argnums: Sequence[int]) -> List[Set[str]]:
    """uint32 trace inputs are packed storage (the only uint32 arrays in the
    datapath); ``packed_argnums`` adds explicit flat positions."""
    taints: List[Set[str]] = []
    for i, var in enumerate(jaxpr.invars):
        t: Set[str] = set()
        if _dtype(var) == jnp.dtype(jnp.uint32) or i in packed_argnums:
            t.add(TAINT_PACKED)
        taints.append(t)
    return taints


def check_function(
    fn,
    *args,
    name: str = "fn",
    packed_argnums: Sequence[int] = (),
) -> List[Finding]:
    """Trace ``fn(*args)`` (args may be ShapeDtypeStructs) and taint-walk it.

    ``packed_argnums`` marks additional *flattened* input positions as packed
    bit-planes; uint32 inputs are seeded automatically.
    """
    closed = jax.make_jaxpr(fn)(*args)
    walk = _TaintWalk(name)
    walk.walk(closed.jaxpr, _seed_taints(closed.jaxpr, packed_argnums))
    return walk.findings


def _compare_cache(init_sds, out_sds, trace_name: str) -> List[Finding]:
    """Pathwise dtype/shape compare of an output cache against its init."""
    path = f"jaxpr:{trace_name}"
    init_leaves, init_tree = jax.tree_util.tree_flatten_with_path(init_sds)
    out_leaves, out_tree = jax.tree_util.tree_flatten_with_path(out_sds)
    if init_tree != out_tree:
        return [
            Finding(
                rule="INV-CACHE-STRUCT",
                path=path,
                line=0,
                symbol=trace_name,
                message="cache pytree structure changed across the step",
                hint="steps must return the cache with the exact init "
                "structure (slots are splice-updated in place)",
            )
        ]
    out: List[Finding] = []
    for (kp, a), (_, b) in zip(init_leaves, out_leaves):
        leaf = jax.tree_util.keystr(kp)
        if jnp.dtype(a.dtype) != jnp.dtype(b.dtype):
            out.append(
                Finding(
                    rule="INV-CACHE-DTYPE",
                    path=path,
                    line=0,
                    symbol=leaf,
                    message=f"cache leaf {leaf} initialized {a.dtype} but the "
                    f"step writes {b.dtype}",
                    hint="derive the write dtype from the cache leaf "
                    "(cache[...].dtype), never a literal — init/write drift "
                    "makes batched decode diverge (the PR 6 bug)",
                )
            )
        if tuple(a.shape) != tuple(b.shape):
            out.append(
                Finding(
                    rule="INV-CACHE-SHAPE",
                    path=path,
                    line=0,
                    symbol=leaf,
                    message=f"cache leaf {leaf} initialized {tuple(a.shape)} "
                    f"but the step returns {tuple(b.shape)}",
                    hint="cache leaves are fixed-capacity ring/linear "
                    "buffers; steps may not grow them",
                )
            )
    return out


def check_cache_contract(init_thunk, step_fn, *step_args, name: str = "cache"):
    """``init_thunk() -> cache``; ``step_fn(cache, *step_args) -> cache``.

    Both run under ``jax.eval_shape`` (abstract — no FLOPs); returns
    INV-CACHE-* findings for any dtype/shape/structure drift.
    """
    init_sds = jax.eval_shape(init_thunk)
    out_sds = jax.eval_shape(step_fn, init_sds, *step_args)
    return _compare_cache(init_sds, out_sds, name)


# ---------------------------------------------------------------------------
# backend sweep
# ---------------------------------------------------------------------------

_M, _K, _N = 8, 64, 16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _backend_cases(backend: str):
    """(case_name, fn, args) triples: one per paper QMM type x precision."""
    kw = packing.packed_len(_K, 1)

    def w1a8(xm, xs, xo, wp, ws, wo, colsum):
        x = QuantTensor(mantissa=xm, scale=xs, offset=xo, bits=8)
        w = QuantTensor(
            mantissa=wp, scale=ws, offset=wo, bits=1,
            packed=True, packed_axis=0, length=_K,
        )
        return QE.qmm(x, w, backend=backend, w_colsum=colsum)

    def w1a1(xm, xs, xo, wp, ws, wo):
        x = QuantTensor(mantissa=xm, scale=xs, offset=xo, bits=1)
        w = QuantTensor(
            mantissa=wp, scale=ws, offset=wo, bits=1,
            packed=True, packed_axis=0, length=_K,
        )
        return QE.qmm(x, w, backend=backend)

    def a8a8(xm, xs, xo, ym, ys, yo):
        x = QuantTensor(mantissa=xm, scale=xs, offset=xo, bits=8)
        y = QuantTensor(mantissa=ym, scale=ys, offset=yo, bits=8)
        return QE.qmm(x, y, backend=backend)

    f32 = jnp.float32
    return [
        (
            "w1a8",  # act x weight, the dense-layer QMM
            w1a8,
            (
                _sds((_M, _K), jnp.uint8), _sds((_M, 1), f32), _sds((_M, 1), f32),
                _sds((kw, _N), jnp.uint32), _sds((1, _N), f32), _sds((1, _N), f32),
                _sds((_N,), jnp.int32),
            ),
        ),
        (
            "w1a1",  # fully binary
            w1a1,
            (
                _sds((_M, _K), jnp.uint8), _sds((), f32), _sds((), f32),
                _sds((kw, _N), jnp.uint32), _sds((1, _N), f32), _sds((1, _N), f32),
            ),
        ),
        (
            "a8a8",  # act x act, the QMM type prior accelerators lack (§II)
            a8a8,
            (
                _sds((_M, _K), jnp.uint8), _sds((), f32), _sds((), f32),
                _sds((_K, _N), jnp.uint8), _sds((), f32), _sds((), f32),
            ),
        ),
    ]


def verify_backends(backends: Optional[Sequence[str]] = None) -> List[Finding]:
    """Taint-walk every registered QMM backend across the QMM-type grid.

    The sweep enumerates the registry's qmm family — a newly registered
    QMM backend is verified with zero edits here.  Scores-family backends
    have a different calling convention and are covered by
    :func:`verify_binary_attention`."""
    out: List[Finding] = []
    for backend in backends or backend_registry.backend_names(family="qmm"):
        for case, fn, args in _backend_cases(backend):
            out.extend(
                check_function(fn, *args, name=f"backend:{backend}:{case}")
            )
    return out


# ---------------------------------------------------------------------------
# model-zoo arch sweep
# ---------------------------------------------------------------------------

# batch / cache capacity / prompt length for the serving traces — the prompt
# must cover patch_stub splicing (encoder.n_positions patches over the prefix)
_B, _T, _S = 2, 32, 16


def _default_archs() -> Tuple[str, ...]:
    from repro.configs import ASSIGNED

    return ASSIGNED + ("bit-bert-base",)


# resolved lazily so importing the verifier doesn't import every config
DEFAULT_ARCHS: Tuple[str, ...] = ()


def _scores_only_backend(name: str) -> bool:
    """Is ``name`` a scores-family-only backend (a bitwise-attention
    engagement when it appears on an attn site record)?"""
    try:
        spec = backend_registry.get_backend(name)
    except (KeyError, ValueError):
        return False
    return "scores" in spec.families and "qmm" not in spec.families


def _site_findings(sites: Sequence[dict], cfg, trace_name: str) -> List[Finding]:
    path = f"jaxpr:{trace_name}"
    out: List[Finding] = []

    def add(rule, symbol, message, hint):
        out.append(
            Finding(
                rule=rule, path=path, line=0, symbol=symbol,
                message=message, hint=hint,
            )
        )

    for s in sites:
        kind = s.get("kind", "")
        site = s.get("site", "")
        bits = s.get("bits")
        mdt = s.get("mantissa_dtype", "")
        if kind == "qlinear":
            if not site:
                add(
                    "INV-SITE-NAME",
                    "<unnamed>",
                    "serve-mode qlinear QMM ran at an unnamed site",
                    "pass name= at the call site — unnamed sites cannot "
                    "receive backend_overrides or autotune phase tags",
                )
                continue
            if bits != s.get("cfg_bits"):
                add(
                    "INV-SITE-BITS",
                    site,
                    f"site quantized activations to {bits} bits but the "
                    f"QuantConfig declares act_bits={s.get('cfg_bits')}",
                    "per-site precision overrides are not part of the "
                    "engine contract; fix the call site or the config",
                )
            expected = "uint8" if (bits or 0) <= 8 else "int32"
            if mdt != expected:
                add(
                    "INV-SITE-MANTISSA",
                    site,
                    f"site produced mantissa dtype {mdt}, quantizer contract "
                    f"says {expected} for {bits}-bit activations",
                    "quantize_activation stores uint8 mantissas up to 8 "
                    "bits; wider precisions use int32",
                )
        elif kind == "attn":
            if _scores_only_backend(s.get("backend", "auto")):
                # bitwise engagement: the site elastically binarizes Q to
                # 1 bit by family contract, whatever attn_act_bits says
                if bits != 1:
                    add(
                        "INV-SITE-BITS",
                        site,
                        f"bitwise attention site ran at {bits} bits; a "
                        "scores-family engagement binarizes to exactly 1",
                        "scores backends consume packed 1-bit planes — the "
                        "site must quantize with bits=1",
                    )
                expected = "uint8"
                hint = (
                    "elastic binarization stores {0,1} uint8 mantissas; "
                    "re-centering does not apply at 1 bit"
                )
            else:
                if bits != cfg.quant.attn_act_bits:
                    add(
                        "INV-SITE-BITS",
                        site,
                        f"attention act x act QMM ran at {bits} bits but "
                        f"attn_act_bits={cfg.quant.attn_act_bits}",
                        "the act x act precision is a single engine mode knob "
                        "(QuantConfig.attn_act_bits)",
                    )
                expected = "int8" if (bits or 0) > 1 else "uint8"
                hint = (
                    "Q.recenter must run before the integer attention MM "
                    "so mantissas fit the int8 MXU path"
                )
            if mdt != expected:
                add(
                    "INV-SITE-MANTISSA",
                    site,
                    f"attention site mantissa dtype {mdt}, expected {expected}",
                    hint,
                )
    return out


def verify_arch(name: str) -> List[Finding]:
    """Trace one model-zoo arch's smoke-size prefill + decode and enforce
    the packed/accum taints, the cache init-vs-write contract, and the
    per-site quantization log."""
    from repro.configs import get_config
    from repro.configs.smoke import smoke_variant
    from repro.models import model_zoo as Z

    cfg = smoke_variant(get_config(name))
    if not cfg.has_decoder:
        return []  # encoder-only: no serving step to verify (assignment rule)

    key = _sds((2,), jnp.uint32)
    sp = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg), key
    )
    init_cache = jax.eval_shape(lambda: Z.init_cache(_B, _T, cfg))
    tokens = _sds((_B, _S), jnp.int32)
    frontend = None
    if cfg.encoder is not None:
        d_in = cfg.encoder.d_input or cfg.d_model
        frontend = _sds((_B, cfg.encoder.n_positions, d_in), jnp.float32)

    findings: List[Finding] = []

    # ---- prefill ----
    trace = f"arch:{name}:prefill"
    with site_log.recording() as sites:
        closed, out_shape = jax.make_jaxpr(
            lambda p, t, c, f: Z.prefill(p, t, cfg, c, f), return_shape=True
        )(sp, tokens, init_cache, frontend)
    walk = _TaintWalk(trace)
    walk.walk(closed.jaxpr, _seed_taints(closed.jaxpr, ()))
    findings.extend(walk.findings)
    findings.extend(_compare_cache(init_cache, out_shape[1], trace))
    findings.extend(_site_findings(sites, cfg, trace))

    # ---- decode ----
    trace = f"arch:{name}:decode"
    tok1 = _sds((_B,), jnp.int32)
    with site_log.recording() as sites:
        closed, out_shape = jax.make_jaxpr(
            lambda p, t, c: Z.decode_step(p, t, cfg, c), return_shape=True
        )(sp, tok1, init_cache)
    walk = _TaintWalk(trace)
    walk.walk(closed.jaxpr, _seed_taints(closed.jaxpr, ()))
    findings.extend(walk.findings)
    findings.extend(_compare_cache(init_cache, out_shape[1], trace))
    findings.extend(_site_findings(sites, cfg, trace))
    return findings


def verify_archs(names: Optional[Sequence[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for name in names or _default_archs():
        out.extend(verify_arch(name))
    return out


# ---------------------------------------------------------------------------
# bitwise-attention sweep (scores backend family)
# ---------------------------------------------------------------------------


def verify_binary_attention() -> List[Finding]:
    """Taint-walk the bitwise-attention path: every scores-family core, plus
    prefill/decode of the 1-bit encoder arch with ``attn.qk -> "binary"``.

    The scores family has its own calling convention (packed rank-4 planes
    in, int32 counts out), so :func:`verify_backends` cannot sweep it; and
    bit-bert-base is encoder-family (the serving arch sweep skips it), so
    the model traces run here directly.  Site assertions: every ``attn.qk``
    record must carry the binary engagement at exactly 1 bit, and the packed
    K cache must round-trip the cache contract.
    """
    import dataclasses
    import functools

    findings: List[Finding] = []

    # ---- every registered scores core keeps the packed/counts taints ----
    q_sds = _sds((1, 4, 6, 2), jnp.uint32)  # (B, H, S, dw) — dh = 48
    k_sds = _sds((1, 2, 5, 2), jnp.uint32)  # (B, G, T, dw), GQA G < H
    for name in backend_registry.backend_names(family="scores"):
        spec = backend_registry.get_backend(name)
        findings.extend(
            check_function(
                functools.partial(spec.run_scores, dh=48),
                q_sds,
                k_sds,
                name=f"scores:{name}",
            )
        )

    # ---- model traces with the binary engagement ----
    from repro.configs import get_config
    from repro.configs.smoke import smoke_variant
    from repro.models import model_zoo as Z

    base = smoke_variant(get_config("bit-bert-base"))
    cfg = dataclasses.replace(
        base,
        quant=dataclasses.replace(
            base.quant, backend_overrides=(("attn.qk", "binary"),)
        ),
    )

    key = _sds((2,), jnp.uint32)
    sp = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg), key
    )
    init_cache = jax.eval_shape(lambda: Z.init_cache(_B, _T, cfg))

    def trace(trace_name: str, fn, *args) -> None:
        with site_log.recording() as sites:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args)
        walk = _TaintWalk(trace_name)
        walk.walk(closed.jaxpr, _seed_taints(closed.jaxpr, ()))
        findings.extend(walk.findings)
        findings.extend(_compare_cache(init_cache, out_shape[1], trace_name))
        findings.extend(_site_findings(sites, cfg, trace_name))
        qk = [s for s in sites if s.get("site") == "attn.qk"]
        path = f"jaxpr:{trace_name}"
        if not qk:
            findings.append(
                Finding(
                    rule="INV-SITE-NAME",
                    path=path,
                    line=0,
                    symbol="attn.qk",
                    message="binary-attention trace recorded no attn.qk site",
                    hint="the override did not engage — check "
                    "QuantConfig.backend_for and _binary_scores_site",
                )
            )
        for s in qk:
            if s.get("backend") != "binary" or s.get("bits") != 1:
                findings.append(
                    Finding(
                        rule="INV-SITE-BITS",
                        path=path,
                        line=0,
                        symbol="attn.qk",
                        message="attn.qk record is not the binary engagement "
                        f"(backend={s.get('backend')!r}, bits={s.get('bits')})",
                        hint="backend_overrides=(('attn.qk', 'binary'),) must "
                        "reach the site and binarize to 1 bit",
                    )
                )

    tokens = _sds((_B, _S), jnp.int32)
    trace(
        "binary-attn:prefill",
        lambda p, t, c: Z.prefill(p, t, cfg, c),
        sp,
        tokens,
        init_cache,
    )
    tok1 = _sds((_B,), jnp.int32)
    trace(
        "binary-attn:decode",
        lambda p, t, c: Z.decode_step(p, t, cfg, c),
        sp,
        tok1,
        init_cache,
    )
    return findings
