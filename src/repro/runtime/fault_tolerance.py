"""Fault tolerance: preemption-safe training runner + elastic rescale.

Failure model at 1000+ nodes (DESIGN.md §4):

* **Node/pod loss & preemption** — the runner installs a SIGTERM/SIGINT
  handler that requests a checkpoint at the next step boundary and exits
  cleanly; restart resumes bit-identically (params, opt state, data cursor
  all inside the checkpoint).  Tested by killing a real training subprocess
  mid-run (tests/test_fault_tolerance.py).
* **Elastic rescale** — checkpoints are mesh-agnostic (stored unsharded per
  host); ``CheckpointManager.restore(shardings=...)`` re-shards onto the new
  mesh, and ``TokenPipeline.reshard`` re-slices the data stream: a job that
  lost a pod restarts on the smaller mesh without data repetition.
* **Stragglers** — inside a pod, TPU SPMD is bulk-synchronous (no per-op
  stragglers; a slow chip slows the lockstep program, which monitoring
  catches as step-time regression).  Across pods the options are (a) the
  default synchronous gradient sync, (b) ``make_compressed_dp_step`` which
  cuts the sync payload 4x, and (c) checkpoint-evict-resume for persistent
  stragglers — the runner exposes step-time percentiles so an external
  orchestrator can trigger (c).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline

__all__ = ["RunnerConfig", "TrainingRunner"]


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    keep_checkpoints: int = 3
    log_every: int = 10


class TrainingRunner:
    """Step loop + checkpoint/restore + preemption handling.

    ``train_step``: jitted (params, opt_state, batch) -> (params, opt_state,
    metrics).  The runner owns nothing about the model — it moves state
    through steps and persists it.
    """

    def __init__(
        self,
        train_step: Callable,
        pipeline: TokenPipeline,
        manager: CheckpointManager,
        cfg: RunnerConfig,
        log_fn: Callable[[str], None] = print,
    ):
        self.train_step = train_step
        self.pipeline = pipeline
        self.manager = manager
        self.cfg = cfg
        self.log = log_fn
        self._preempted = False
        self._prev_handlers: Dict[int, object] = {}
        self.step_times: List[float] = []

    # -- preemption ------------------------------------------------------
    def install_signal_handlers(self) -> None:
        """Request a checkpoint-and-exit on SIGTERM/SIGINT.

        The previous handlers are saved — and CHAINED: whatever the host
        process had installed (an orchestrator's own drain logic, pytest's
        KeyboardInterrupt machinery) still runs after the runner marks
        itself preempted.  :meth:`restore_signal_handlers` puts the saved
        handlers back; idempotent (a second install does not clobber the
        saved originals with the runner's own handler).
        """
        if self._prev_handlers:
            return  # already installed; keep the original saved handlers

        def handler(signum, frame):
            self.log(f"[runner] signal {signum}: checkpoint at next boundary")
            self._preempted = True
            prev = self._prev_handlers.get(signum)
            if callable(prev):  # chain (SIG_DFL/SIG_IGN are ints, not callables)
                prev(signum, frame)

        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev_handlers[sig] = signal.signal(sig, handler)

    def restore_signal_handlers(self) -> None:
        """Reinstall the handlers that were active before ``install``."""
        for sig, prev in self._prev_handlers.items():
            signal.signal(sig, prev if prev is not None else signal.SIG_DFL)
        self._prev_handlers = {}

    # -- resume ----------------------------------------------------------
    def try_restore(self, params, opt_state, shardings=None):
        step = self.manager.latest_step()
        if step is None:
            return 0, params, opt_state
        step, tree, extras = self.manager.restore(
            step, like={"params": params, "opt": opt_state}, shardings=shardings
        )
        self.pipeline.restore(extras["pipeline"])
        self.log(f"[runner] resumed from step {step}")
        return step, tree["params"], tree["opt"]

    def _save(self, step: int, params, opt_state) -> None:
        extras = {"pipeline": self.pipeline.state(), "step": step}
        path = self.manager.save(step, {"params": params, "opt": opt_state}, extras)
        self.log(f"[runner] checkpoint step {step} -> {path}")

    # -- main loop -------------------------------------------------------
    def run(self, params, opt_state, start_step: int = 0):
        metrics_hist: List[Dict[str, float]] = []
        step = start_step
        while step < self.cfg.total_steps:
            batch = {
                k: jax.numpy.asarray(v) for k, v in self.pipeline.next().items()
            }
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time_s"] = dt
                metrics_hist.append({"step": step, **m})
                self.log(
                    f"[runner] step {step} loss {m['loss']:.4f} "
                    f"({dt*1e3:.0f} ms, p50 {self.p50*1e3:.0f} ms)"
                )
            if step % self.cfg.checkpoint_every == 0 or self._preempted:
                self._save(step, params, opt_state)
                if self._preempted:
                    self.log("[runner] exiting after preemption checkpoint")
                    break
        return params, opt_state, metrics_hist

    @property
    def p50(self) -> float:
        return float(np.median(self.step_times)) if self.step_times else 0.0

    @property
    def p99(self) -> float:
        return (
            float(np.percentile(self.step_times, 99)) if self.step_times else 0.0
        )
