"""Sharding rules: params / optimizer state / batches / caches -> PartitionSpec.

Mesh contract (launch/mesh.py): ``(data, model)`` single-pod or
``(pod, data, model)`` multi-pod.  ``pod`` is pure DP (the scarce cross-pod
links carry only gradient/param sync); ``data`` is in-pod DP (+ sequence
parallelism fallback); ``model`` is TP/EP.

Rules (Megatron-style, packed-weight aware):

* column-parallel (q/k/v/up/gate/in_*, router-less): shard the OUTPUT dim
  over ``model``; activations enter replicated, leave model-sharded.
* row-parallel (o/down/out*): shard the INPUT dim over ``model`` — for
  bit-packed weights that is the PACKED axis, which is why packing is done
  in units of 32 along K and K is kept a multiple of 32*|model| (DESIGN §7).
* experts (E, K, N): shard E over ``model`` (expert parallelism).
* embeddings (V, D): V over ``model`` (vocab-parallel logits).
* KV caches: batch over ``data`` when divisible; else sequence over
  ``data`` (SP — the long_500k b=1 cell).  Heads over ``model`` when
  divisible, else head_dim, else replicate.
* everything 1D/scalar: replicated.

Every rule is divisibility-guarded: a dim is only sharded if the axis size
divides it, so ONE rule set serves all 10 archs x 4 shapes (the dry-run
sweeps them all).  Scan-stacked leaves (under ``period``) have a leading
scan dim that is never sharded — specs shift right by one.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_pspec",
    "params_shardings",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "logical_batch_spec",
]

_COL_PARALLEL = {
    "q", "k", "v", "up", "gate", "in_proj", "in_x", "in_gate",
    "gate_a", "gate_i", "q_up", "q_down", "kv_down", "k_rope", "k_up",
    "v_up", "q_proj", "proj", "stub_proj",
}
_ROW_PARALLEL = {"o", "down", "out", "out_proj"}
_EMBED = {"embedding", "unembedding"}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _shard_if(dim: int, size: int, axis: str) -> Optional[str]:
    return axis if size > 1 and dim % size == 0 else None


def param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one parameter/optimizer leaf."""
    msize = _axis_size(mesh, "model")
    names = [str(p) for p in path]
    stacked = 1 if "period" in names else 0  # scan dim leads
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    # identify the owning module name (q/k/v/up/...) for w-like leaves
    owner = parent if leaf in ("w", "w_packed", "w_scale", "w_offset", "w_colsum") else leaf

    def spec(*entries):
        return P(*([None] * stacked + list(entries)))

    ndim = len(shape) - stacked

    if leaf in _EMBED or owner in _EMBED:
        if ndim == 2:
            return spec(_shard_if(shape[stacked], msize, "model"), None)
        return P()

    if leaf == "pos_embedding":
        return P()

    if owner == "router":
        return P()  # tiny + accuracy-critical: replicated

    # Expert stacks carry a leading E dim beyond the 2D (or packed-2D) base:
    #   w/w_packed (E, K[, /32], N), w_scale/offset (E, 1, N), w_colsum (E, N)
    # — all sharded over E (expert parallelism).
    is_w_leaf = leaf in ("w", "w_packed", "w_scale", "w_offset", "w_colsum")
    if is_w_leaf:
        base = {"w": 2, "w_packed": 2, "w_scale": 2, "w_offset": 2, "w_colsum": 1}[leaf]
        if ndim > base:  # expert-stacked
            return spec(
                _shard_if(shape[stacked], msize, "model"), *([None] * (ndim - 1))
            )

    if owner in _COL_PARALLEL:
        if leaf in ("w", "w_packed"):  # (K[, /32], N): shard N
            return spec(None, _shard_if(shape[-1], msize, "model"))
        if leaf in ("w_scale", "w_offset"):  # (1, N)
            return spec(None, _shard_if(shape[-1], msize, "model"))
        if leaf == "w_colsum":  # (N,)
            return spec(_shard_if(shape[-1], msize, "model"))

    if owner in _ROW_PARALLEL:
        if leaf in ("w", "w_packed"):  # (K[, /32], N): shard K
            return spec(_shard_if(shape[stacked], msize, "model"), None)
        return spec(*([None] * ndim))  # scales/colsums over N=d_model: replicate

    # norms, gains, convs, A_log, biases: replicate
    return P()


def _add_fsdp(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Layer a ZeRO/FSDP 'data'-axis shard onto the largest still-unsharded
    dim.  Training-only: latent fp32 weights + two Adam moments are 12
    bytes/param — at 671B params they only fit when *fully* sharded
    (8 TB / 512 chips); XLA re-gathers per layer inside the scan (classic
    FSDP schedule).  Serving params skip this (packed weights are 16x
    smaller; TP-only keeps decode all-gather-free)."""
    dsize = _axis_size(mesh, "data")
    if dsize <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    candidates = [
        (shape[i], i)
        for i in range(len(shape))
        if entries[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize
    ]
    if not candidates:
        return spec
    _, best = max(candidates)
    entries[best] = "data"
    return P(*entries)


def params_shardings(params, mesh: Mesh, fsdp: bool = False):
    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        spec = param_pspec(keys, shape, mesh)
        if fsdp and len(shape) >= 2:
            spec = _add_fsdp(spec, shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------


def logical_batch_spec(batch_size: int, seq_len: int, mesh: Mesh) -> P:
    """(B, S) spec: batch over (pod, data) when divisible, else SP over data."""
    dp = list(data_axes(mesh))
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp])) if dp else 1
    if dp and batch_size % dp_size == 0:
        return P(tuple(dp), None)
    # sequence parallelism fallback (long_500k: B=1)
    if "pod" in dp and batch_size % _axis_size(mesh, "pod") == 0:
        return P("pod", _shard_if(seq_len, _axis_size(mesh, "data"), "data"))
    return P(None, _shard_if(seq_len, dp_size and _axis_size(mesh, "data"), "data"))


def batch_shardings(batch_shape: dict, mesh: Mesh):
    """Shardings for {"tokens": (B,S), optional "frontend": (B,T,D)}."""
    out = {}
    for k, v in batch_shape.items():
        shape = v.shape if hasattr(v, "shape") else v
        if k == "tokens":
            out[k] = NamedSharding(mesh, logical_batch_spec(shape[0], shape[1], mesh))
        else:
            spec = logical_batch_spec(shape[0], shape[1], mesh)
            out[k] = NamedSharding(mesh, P(*(list(spec) + [None] * (len(shape) - 2))))
    return out


def cache_pspec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh, batch: int) -> P:
    """KV/SSM cache leaves. Layouts:
    kv: (B,T,kvH,dh) / mla: (B,T,R) / ssd: (B,H,P,N) / conv: (B,w,C) /
    rglru h: (B,di); scan-stacked versions carry a leading period dim."""
    names = [str(p) for p in path]
    leaf = names[-1]
    stacked = 1 if "period" in names else 0
    msize = _axis_size(mesh, "model")
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp])) if dp else 1
    ndim = len(shape) - stacked

    def spec(*entries):
        return P(*([None] * stacked + list(entries)))

    if leaf in ("pos",):
        return P()
    if ndim == 0 or ndim == 1:
        return P()

    b_dim = shape[stacked]
    b_spec = tuple(dp) if (dp and b_dim % dp_size == 0) else None

    if leaf in ("k", "v") and ndim == 4:  # (B,T,kvH,dh)
        kvh, dh = shape[stacked + 2], shape[stacked + 3]
        if kvh % msize == 0 and msize > 1:
            return spec(b_spec, None, "model", None)
        if dh % msize == 0 and msize > 1:
            return spec(b_spec, None, None, "model")
        return spec(b_spec, None, None, None)
    if leaf == "ckv" and ndim == 3:  # (B,T,R): latent over model
        r = shape[stacked + 2]
        return spec(b_spec, None, _shard_if(r, msize, "model"))
    if leaf == "k_rope" and ndim == 3:
        return spec(b_spec, None, None)
    if leaf == "ssm" and ndim == 4:  # (B,H,P,N)
        h = shape[stacked + 1]
        return spec(b_spec, _shard_if(h, msize, "model"), None, None)
    if leaf == "conv" and ndim == 3:  # (B,w,C)
        c = shape[stacked + 2]
        return spec(b_spec, None, _shard_if(c, msize, "model"))
    if leaf == "h" and ndim == 2:  # (B,di)
        return spec(b_spec, _shard_if(shape[stacked + 1], msize, "model"))
    if leaf == "encoder_out" and ndim == 3:
        return spec(b_spec, None, None)
    # scales/offsets and anything else
    return spec(*([None] * ndim))


def cache_shardings(cache, mesh: Mesh, batch: int):
    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        return NamedSharding(mesh, cache_pspec(keys, shape, mesh, batch))

    return jax.tree_util.tree_map_with_path(one, cache)
