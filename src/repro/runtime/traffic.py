"""Synthetic open-loop serving traffic + the BENCH_serve.json schema.

Open-loop means arrivals are independent of service: a Poisson process
(exponential inter-arrival gaps at ``rate_rps``) stamps each request with an
``arrival_s`` the engine honors regardless of how fast it is draining —
queueing delay shows up in the latency percentiles instead of silently
throttling the offered load (closed-loop generators hide saturation).

Everything is seeded: the same ``TrafficConfig`` always produces the same
request set (prompts, lengths, arrival times), which is what lets
``BENCH_serve.json`` act as a perf-trajectory artifact — later PRs rerun the
identical workload and diff rps/p50/p99.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.serve_loop import Request

__all__ = [
    "TrafficConfig",
    "generate_requests",
    "summarize_bench",
    "summarize_availability",
    "validate_bench",
    "save_bench",
    "load_bench",
    "BENCH_SCHEMA_VERSION",
    "BENCH_REQUIRED_KEYS",
]

BENCH_SCHEMA_VERSION = 2
# contract checked by tests + the CI smoke cells.  v2 adds "availability":
# the perf trajectory records robustness (success rate, deadline misses,
# retries, faults survived), not just latency.
BENCH_REQUIRED_KEYS = ("rps", "p50_ms", "p99_ms", "config", "availability")

#: event kinds (ServeEngine.last_events) counted as faults the run absorbed
_FAULT_EVENT_KINDS = (
    "step_fault",
    "backend_fault",
    "nan_logits",
    "prefill_fault",
    "snapshot_failed",
)


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Open-loop workload description (all distributions seeded)."""

    n_requests: int = 16
    rate_rps: float = 8.0  # Poisson arrival rate; <=0 -> all arrive at t=0
    prompt_len: Tuple[int, int] = (4, 12)  # inclusive uniform range
    new_tokens: Tuple[int, int] = (4, 16)  # inclusive uniform range
    temperature: float = 0.0
    deadline_s: Optional[float] = None  # per-request deadline from arrival
    seed: int = 0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["prompt_len"] = list(self.prompt_len)
        d["new_tokens"] = list(self.new_tokens)
        return d


def generate_requests(tc: TrafficConfig, vocab_size: int) -> List[Request]:
    """Materialize the workload: deterministic in (tc, vocab_size)."""
    rng = np.random.default_rng(tc.seed)
    if tc.rate_rps > 0:
        gaps = rng.exponential(1.0 / tc.rate_rps, size=tc.n_requests)
        arrivals = np.cumsum(gaps)
    else:
        arrivals = np.zeros(tc.n_requests)
    out: List[Request] = []
    for i in range(tc.n_requests):
        plen = int(rng.integers(tc.prompt_len[0], tc.prompt_len[1] + 1))
        nnew = int(rng.integers(tc.new_tokens[0], tc.new_tokens[1] + 1))
        prompt = rng.integers(0, vocab_size, size=(plen,)).astype(np.int32)
        out.append(
            Request(
                prompt=prompt,
                max_new_tokens=nnew,
                temperature=tc.temperature,
                arrival_s=float(arrivals[i]),
                deadline_s=tc.deadline_s,
            )
        )
    return out


def _percentile_ms(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q) * 1e3) if xs else 0.0


def _terminal_state(r: Request) -> str:
    """The request's terminal state, tolerating pre-robustness callers that
    hand-build requests without driving the engine's state machine."""
    state = getattr(r, "state", None)
    if state in ("ok", "failed", "deadline"):
        return state
    return "ok" if r.output else "failed"


def summarize_availability(
    requests: List[Request], events: Optional[List[Dict]] = None
) -> Dict:
    """The robustness block of BENCH_serve.json.

    ``events`` is ``ServeEngine.last_events`` — the fault/retry/demotion
    trace of the run.  "p99_under_faults_ms" is the p99 token latency of
    THIS run; when the config carries a fault plan, that number is the
    paper-thesis availability metric (tail latency while absorbing faults).
    """
    events = events or []
    states = [_terminal_state(r) for r in requests]
    n = len(requests)
    n_ok = states.count("ok")
    n_deadline = states.count("deadline")
    lats: List[float] = []
    for r in requests:
        if r.token_times:
            lats.append(r.token_times[0] - r.arrival_s)
            lats.extend(np.diff(np.asarray(r.token_times)).tolist())
    kinds = [e.get("kind") for e in events]
    return {
        "n_ok": n_ok,
        "n_failed": states.count("failed"),
        "n_deadline_missed": n_deadline,
        "success_rate": (n_ok / n) if n else 1.0,
        "deadline_miss_rate": (n_deadline / n) if n else 0.0,
        "retries": int(sum(getattr(r, "retries", 0) for r in requests)),
        "faults": sum(kinds.count(k) for k in _FAULT_EVENT_KINDS),
        "demotions": kinds.count("demote"),
        "snapshots": kinds.count("snapshot"),
        "p99_under_faults_ms": _percentile_ms(lats, 99),
    }


def summarize_bench(
    requests: List[Request],
    wall_s: float,
    config: Optional[Dict] = None,
    events: Optional[List[Dict]] = None,
) -> Dict:
    """Condense a served request set into the BENCH_serve.json record.

    Token latency distribution = per-request time-to-first-token (from
    arrival, so queueing delay counts) plus every inter-token gap; ``rps``
    is completed requests over the wall clock of the whole run.  Pass the
    engine's ``last_events`` as ``events`` so the availability block can
    count faults, retries, and backend demotions.
    """
    lats: List[float] = []
    ttfts: List[float] = []
    n_tokens = 0
    for r in requests:
        if not r.token_times:
            continue
        n_tokens += len(r.token_times)
        ttft = r.token_times[0] - r.arrival_s
        ttfts.append(ttft)
        lats.append(ttft)
        lats.extend(np.diff(np.asarray(r.token_times)).tolist())
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "config": dict(config or {}),
        "rps": (len(requests) / wall_s) if wall_s > 0 else 0.0,
        "p50_ms": _percentile_ms(lats, 50),
        "p99_ms": _percentile_ms(lats, 99),
        "ttft_p50_ms": _percentile_ms(ttfts, 50),
        "ttft_p99_ms": _percentile_ms(ttfts, 99),
        "tokens_per_s": (n_tokens / wall_s) if wall_s > 0 else 0.0,
        "n_requests": len(requests),
        "n_tokens": n_tokens,
        "wall_s": wall_s,
        "availability": summarize_availability(requests, events),
    }


def validate_bench(doc: Dict) -> Dict:
    missing = [k for k in BENCH_REQUIRED_KEYS if k not in doc]
    if missing:
        raise ValueError(f"BENCH_serve.json missing keys: {missing}")
    for k in ("rps", "p50_ms", "p99_ms"):
        if not isinstance(doc[k], (int, float)):
            raise ValueError(f"BENCH_serve.json key {k!r} must be numeric")
    if not isinstance(doc["config"], dict):
        raise ValueError("BENCH_serve.json 'config' must be an object")
    avail = doc["availability"]
    if not isinstance(avail, dict):
        raise ValueError("BENCH_serve.json 'availability' must be an object")
    for k in ("success_rate", "deadline_miss_rate", "retries"):
        if not isinstance(avail.get(k), (int, float)):
            raise ValueError(
                f"BENCH_serve.json availability key {k!r} must be numeric"
            )
    return doc


def save_bench(path: str, doc: Dict) -> None:
    validate_bench(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load_bench(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    validate_bench(doc)
    return doc
