"""Deterministic fault injection for the serving runtime.

BETA's availability story ("a lost host triggers re-shard + resume rather
than a dead replica") is only testable if every failure mode is
*reproducible*: a chaos run whose faults land at different places on every
execution cannot be diffed against an unfailed oracle.  This module is the
reproducibility layer — a :class:`FaultPlan` names exactly which decode
ticks fail, which logits go NaN, which registry backend raises and how
often, and which snapshot writes crash; :class:`FaultInjector` threads that
plan through ``ServeEngine``'s hook points with one-shot semantics, so the
same plan against the same workload produces the same failure trace, run
after run.

Fault vocabulary (each maps to one hook in ``runtime.serve_loop``):

* ``decode_fail_ticks``    — raise :class:`InjectedFault` before the decode
  step at these tick indices, once per tick (the retry of the same tick
  succeeds: a *transient* step failure).
* ``decode_fail_attempts`` — raise before these decode *attempt* ordinals
  (attempts count retries too, so a long run of ordinals models a
  *persistent* failure that exhausts the retry budget).
* ``backend_fail``         — ``{backend_name: n}``: the next ``n`` decode
  attempts raise :class:`BackendFault` naming that backend, as long as the
  engine has not demoted it — models a kernel (e.g. the fused Pallas
  backend off-TPU) that fails every time until dispatch routes around it.
* ``nan_ticks``            — ``{tick: slot}``: overwrite that slot's logits
  row with NaN after the decode at ``tick`` (a numerics escape the engine
  must contain to one request).
* ``delay_ticks``          — ``{tick: seconds}``: sleep before the decode at
  ``tick`` (an injected latency spike; drives deadline-miss paths).
* ``every_tick_delay_s``   — constant per-tick sleep (slows a run down so a
  test can SIGKILL it mid-batch deterministically).
* ``prefill_fail_rids``    — ``{rid: n}``: the next ``n`` admissions of that
  request raise during prefill.
* ``snapshot_fail_at``     — snapshot ordinals whose write raises
  (a checkpoint-write crash; the engine must keep serving).

``FaultPlan()`` (all fields empty) is the no-op default; the injector for it
never fires, so production serving pays one attribute check per hook.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

__all__ = [
    "InjectedFault",
    "BackendFault",
    "FaultPlan",
    "FaultInjector",
    "parse_fault_plan",
]


class InjectedFault(RuntimeError):
    """A failure placed by a :class:`FaultPlan` (base for all injected kinds)."""


class BackendFault(InjectedFault):
    """A failure attributed to one registry backend.

    Carries ``.backend`` so the engine's degradation policy can count
    failures per backend and demote the repeat offender.  Real kernels may
    raise this too — the engine treats any ``BackendFault`` identically,
    injected or not.
    """

    def __init__(self, backend: str, message: str = ""):
        super().__init__(message or f"backend {backend!r} failed")
        self.backend = backend


def _int_keys(d: Optional[Dict]) -> Dict[int, float]:
    return {int(k): v for k, v in (d or {}).items()}


def _as_map(spec: Dict, key: str) -> Dict:
    """Fetch a mapping-valued plan field, rejecting wrong-shaped JSON loudly."""
    val = spec.get(key, {})
    if not isinstance(val, dict):
        raise ValueError(
            f"fault plan field {key!r} must be a JSON object "
            f"(e.g. {{\"3\": 1}}), got {type(val).__name__}"
        )
    return val


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A complete, deterministic failure schedule for one serving run.

    Frozen so a plan can ride inside a bench config dict unchanged; all
    mutable firing state lives in the :class:`FaultInjector` built from it.
    """

    decode_fail_ticks: Tuple[int, ...] = ()
    decode_fail_attempts: Tuple[int, ...] = ()
    backend_fail: Dict[str, int] = dataclasses.field(default_factory=dict)
    nan_ticks: Dict[int, int] = dataclasses.field(default_factory=dict)
    delay_ticks: Dict[int, float] = dataclasses.field(default_factory=dict)
    every_tick_delay_s: float = 0.0
    prefill_fail_rids: Dict[int, int] = dataclasses.field(default_factory=dict)
    snapshot_fail_at: Tuple[int, ...] = ()

    def is_noop(self) -> bool:
        return not (
            self.decode_fail_ticks
            or self.decode_fail_attempts
            or self.backend_fail
            or self.nan_ticks
            or self.delay_ticks
            or self.every_tick_delay_s
            or self.prefill_fail_rids
            or self.snapshot_fail_at
        )

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["decode_fail_ticks"] = list(self.decode_fail_ticks)
        d["decode_fail_attempts"] = list(self.decode_fail_attempts)
        d["snapshot_fail_at"] = list(self.snapshot_fail_at)
        # JSON objects carry string keys; normalize so to_dict/parse round-trip
        d["nan_ticks"] = {str(k): int(v) for k, v in self.nan_ticks.items()}
        d["delay_ticks"] = {str(k): float(v) for k, v in self.delay_ticks.items()}
        d["prefill_fail_rids"] = {
            str(k): int(v) for k, v in self.prefill_fail_rids.items()
        }
        return d

    @classmethod
    def sample(
        cls,
        seed: int,
        horizon: int,
        *,
        p_decode_fail: float = 0.05,
        p_nan: float = 0.0,
        n_slots: int = 4,
        max_delay_s: float = 0.0,
    ) -> "FaultPlan":
        """A random-but-deterministic chaos plan over ``horizon`` ticks.

        The same seed always yields the same plan — chaos tests stay
        reproducible while still covering varied fault placements.
        """
        rng = np.random.default_rng(seed)
        ticks = np.arange(horizon)
        fail = tuple(int(t) for t in ticks[rng.random(horizon) < p_decode_fail])
        nan = {
            int(t): int(rng.integers(0, n_slots))
            for t in ticks[rng.random(horizon) < p_nan]
        }
        delay: Dict[int, float] = {}
        if max_delay_s > 0:
            spikes = ticks[rng.random(horizon) < 0.1]
            delay = {int(t): float(rng.uniform(0, max_delay_s)) for t in spikes}
        return cls(decode_fail_ticks=fail, nan_ticks=nan, delay_ticks=delay)


def parse_fault_plan(spec) -> FaultPlan:
    """Build a :class:`FaultPlan` from a JSON string, a dict, or ``None``.

    The CLI surface (``--fault-plan '{"decode_fail_ticks": [1]}'``): JSON
    object keys arrive as strings, so integer-keyed maps are normalized.
    Unknown keys are an error — a typo'd fault name must not silently
    become a no-op chaos run.
    """
    if spec is None:
        return FaultPlan()
    if isinstance(spec, FaultPlan):
        return spec
    if isinstance(spec, str):
        spec = json.loads(spec)
    if not isinstance(spec, dict):
        raise ValueError(f"fault plan must be a JSON object, got {type(spec).__name__}")
    known = {f.name for f in dataclasses.fields(FaultPlan)}
    unknown = set(spec) - known
    if unknown:
        raise ValueError(f"unknown fault plan keys: {sorted(unknown)}")
    return FaultPlan(
        decode_fail_ticks=tuple(int(t) for t in spec.get("decode_fail_ticks", ())),
        decode_fail_attempts=tuple(
            int(t) for t in spec.get("decode_fail_attempts", ())
        ),
        backend_fail={str(k): int(v) for k, v in _as_map(spec, "backend_fail").items()},
        nan_ticks={int(k): int(v) for k, v in _as_map(spec, "nan_ticks").items()},
        delay_ticks={int(k): float(v) for k, v in _as_map(spec, "delay_ticks").items()},
        every_tick_delay_s=float(spec.get("every_tick_delay_s", 0.0)),
        prefill_fail_rids=_int_keys(_as_map(spec, "prefill_fail_rids")),
        snapshot_fail_at=tuple(int(t) for t in spec.get("snapshot_fail_at", ())),
    )


class FaultInjector:
    """Stateful executor of one :class:`FaultPlan` over one serving run.

    One-shot discipline: a tick-keyed fault fires exactly once per tick
    value (the engine's retry of the same tick proceeds clean), a
    count-keyed fault (``backend_fail``, ``prefill_fail_rids``) decrements
    until exhausted.  ``injected`` counts every fault actually delivered,
    which feeds the availability block of BENCH_serve.json.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, *, sleep=None):
        import time

        self.plan = plan or FaultPlan()
        self._sleep = sleep or time.sleep
        self._fired: set = set()
        self._backend_left = dict(self.plan.backend_fail)
        self._prefill_left = dict(self.plan.prefill_fail_rids)
        self._attempts = 0
        self.injected = 0

    def _fire_once(self, key) -> bool:
        if key in self._fired:
            return False
        self._fired.add(key)
        self.injected += 1
        return True

    # -- engine hook points --------------------------------------------------

    def before_decode(self, tick: int, demoted: Iterable[str] = ()) -> None:
        """Called before every decode attempt (including retries of a tick).

        May sleep (latency spike) and may raise ``InjectedFault`` /
        ``BackendFault``.  Backend faults stop firing for backends the
        engine already demoted — the failure belongs to the datapath, not
        the tick.
        """
        attempt = self._attempts
        self._attempts += 1
        delay = self.plan.every_tick_delay_s + self.plan.delay_ticks.get(tick, 0.0)
        if delay > 0 and self._fire_once(("delay", tick, attempt)):
            self._sleep(delay)
        demoted = set(demoted)
        for backend, left in self._backend_left.items():
            if left > 0 and backend not in demoted:
                self._backend_left[backend] = left - 1
                self.injected += 1
                raise BackendFault(backend, f"injected failure of {backend!r}")
        if attempt in self.plan.decode_fail_attempts:
            self.injected += 1
            raise InjectedFault(f"injected decode failure (attempt {attempt})")
        if tick in self.plan.decode_fail_ticks and self._fire_once(("tick", tick)):
            raise InjectedFault(f"injected decode failure (tick {tick})")

    def corrupt_logits(self, tick: int, logits: np.ndarray) -> np.ndarray:
        """NaN out one slot's logits row after the decode at ``tick``."""
        slot = self.plan.nan_ticks.get(tick)
        if slot is None or not self._fire_once(("nan", tick)):
            return logits
        out = np.array(logits, copy=True)
        out[slot % out.shape[0]] = np.nan
        return out

    def before_prefill(self, rid: int) -> None:
        left = self._prefill_left.get(rid, 0)
        if left > 0:
            self._prefill_left[rid] = left - 1
            self.injected += 1
            raise InjectedFault(f"injected prefill failure (rid {rid})")

    def on_snapshot(self, ordinal: int) -> None:
        if ordinal in self.plan.snapshot_fail_at and self._fire_once(("snap", ordinal)):
            raise InjectedFault(f"injected snapshot-write crash (ordinal {ordinal})")
