"""Distributed QAT training step builder (pjit) + microbatching + pod sync.

``make_train_step`` returns a jitted SPMD step:

    (params, opt_state, batch) -> (params, opt_state, metrics)

* **Parallelism**: params/opt-state sharded by runtime.sharding (TP/EP over
  ``model``); batch over ``(pod, data)``; XLA SPMD inserts the gradient
  all-reduces.  This is the function the dry-run lowers for every
  ``train_4k`` cell.
* **Microbatching**: ``accum_steps`` splits the per-step batch along B and
  accumulates grads in a ``lax.scan`` — activation memory scales with the
  microbatch, which is what lets deepseek-v3-671b's 1M-token steps compile
  within a 16 GB/chip budget (EXPERIMENTS.md §Dry-run).
* **Compressed pod sync** (beyond-paper, see optim.compression): an
  explicit int8 error-feedback all-reduce variant, exposed as
  ``make_compressed_dp_step`` over an explicit shard_map for DP-only
  configs, plus analytic byte accounting used in §Perf.  The default pjit
  path keeps XLA-managed fp32 reductions (control arm).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model_zoo as Z
from repro.optim import adamw, compression
from repro.runtime import sharding as SH

__all__ = ["TrainConfig", "make_train_step", "make_compressed_dp_step", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    accum_steps: int = 1
    remat: bool = True
    aux_weight: float = 0.01


def init_train_state(key, cfg: ArchConfig):
    params = Z.init_params(key, cfg)
    return params, adamw.init_state(params)


def _loss(params, batch, cfg: ArchConfig, aux_weight: float):
    return Z.loss_fn(params, batch, cfg, mode="train", aux_weight=aux_weight)


# ---------------------------------------------------------------------------
# packed FSDP gather: binarize + bit-pack BEFORE the weight all-gather
# ---------------------------------------------------------------------------
#
# FSDP keeps fp32 latents sharded over `data`; every layer use all-gathers
# them — for deepseek-v3 that is ~45 GB of fp32 per MoE layer to EVERY chip
# (the dominant memory+collective term of the train_4k baseline, §Perf).
# But the QAT forward only consumes alpha * sign(w): sign bits pack 32-to-a-
# word, so we binarize and pack ON THE SHARD, constrain the PACKED tensor to
# the TP-only sharding (that constraint is where the gather happens — 32x
# fewer wire bytes, measured 31.8x in the probe), unpack post-gather, and
# route gradients back to the latents with the standard STE (custom_vjp:
# the fp32 latent never appears in the forward graph, so XLA cannot
# "helpfully" gather it).


def _ste_packed_binarize(mesh: Mesh, packed_spec, k_dim: int):
    from repro.core import packing

    @jax.custom_vjp
    def f(w):
        return _value(w)

    def _value(w):
        alpha = jnp.mean(jnp.abs(w), axis=-2, keepdims=True)
        bits = (w >= 0).astype(jnp.uint32)
        packed = packing.pack_bits(bits, 1, axis=-2)
        packed = jax.lax.with_sharding_constraint(
            packed, NamedSharding(mesh, packed_spec)
        )
        pm1 = packing.unpack_bits(packed, 1, k_dim, axis=-2, dtype=jnp.int8)
        pm1 = pm1.astype(jnp.bfloat16) * 2.0 - 1.0
        return pm1 * alpha.astype(jnp.bfloat16)

    def fwd(w):
        alpha = jnp.mean(jnp.abs(w), axis=-2, keepdims=True)
        return _value(w), alpha

    def bwd(alpha, g):
        return ((g.astype(jnp.float32) * alpha),)  # STE through sign

    f.defvjp(fwd, bwd)
    return f


_QMM_OWNERS = SH._COL_PARALLEL | SH._ROW_PARALLEL | {"up", "gate", "down"}


def prebinarize_params(params, cfg: ArchConfig, mesh: Mesh):
    """Replace every QMM latent 'w' with its packed-gather STE binarization.

    Norms/routers/embeddings/frontends pass through untouched; the returned
    tree is what the model consumes with ``quant.prebinarize_gather`` set.
    """

    def walk(node, path):
        if isinstance(node, dict):
            if "w" in node and len(node) == 1 and not any(
                s in path for s in ("router", "stub_proj")
            ):
                parent = path[-1] if path else ""
                if parent in _QMM_OWNERS:
                    w = node["w"]
                    packed_shape = list(w.shape)
                    packed_shape[-2] = -(-w.shape[-2] // 32)
                    spec = SH.param_pspec(
                        path + ("w_packed",), tuple(packed_shape), mesh
                    )
                    fn = _ste_packed_binarize(mesh, spec, w.shape[-2])
                    return {"w": fn(w)}
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (str(i),)) for i, v in enumerate(node))
        return node

    return walk(params, ())


def make_train_step(
    cfg: ArchConfig,
    tcfg: TrainConfig,
    mesh: Mesh,
    batch_shape: dict,
):
    """Build the pjit'd train step for (arch, mesh, global batch shape).

    batch_shape: {"tokens": (B, S)[, "frontend": (B, T, Din)]} — concrete
    shapes so shardings can be resolved ahead of time (AOT-lowerable).
    """
    # Remat policy: block-level remat lives inside models.transformer
    # (scan body checkpointed in train mode); tcfg.remat kept for ablation.
    base_loss = functools.partial(_loss, cfg=cfg, aux_weight=tcfg.aux_weight)
    if cfg.quant.enabled and cfg.quant.prebinarize_gather:

        def loss_fn(params, batch):
            return base_loss(prebinarize_params(params, cfg, mesh), batch)

    else:
        loss_fn = base_loss

    accum = tcfg.accum_steps

    def step(params, opt_state, batch):
        if accum == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            b = batch["tokens"].shape[0]
            micro = b // accum
            sliced = jax.tree.map(
                lambda a: a[: micro * accum].reshape(accum, micro, *a.shape[1:]),
                batch,
            )

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.float32(0), "aux": jnp.float32(0), "nll": jnp.float32(0)}
            (grads, msum), _ = jax.lax.scan(acc_body, (g0, m0), sliced)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, msum)

        params2, opt2, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, tcfg.optimizer
        )
        metrics = dict(metrics, **opt_metrics)
        return params2, opt2, metrics

    # resolve shardings (FSDP over `data` for latent weights + Adam moments)
    p_leaves = jax.eval_shape(lambda k: Z.init_params(k, cfg), jax.random.PRNGKey(0))
    p_sh = SH.params_shardings(p_leaves, mesh, fsdp=True)
    opt_sh = adamw.OptState(
        mu=p_sh, nu=p_sh, step=NamedSharding(mesh, P())
    )
    b_sh = SH.batch_shardings(batch_shape, mesh)

    return jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, b_sh),
        out_shardings=(p_sh, opt_sh, None),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# explicit compressed-DP step (shard_map) — the distributed-optimization trick
# ---------------------------------------------------------------------------


def make_compressed_dp_step(
    cfg: ArchConfig,
    tcfg: TrainConfig,
    mesh: Mesh,
    compress: bool = True,
):
    """Pure-DP train step with explicit int8 error-feedback gradient
    all-reduce across every data axis (pod + data).  Params replicated —
    the cross-pod regime where wire bytes, not FLOPs, bound step time.
    Wire traffic: 4x fewer gradient bytes than fp32 psum (see
    benchmarks/compression_bench.py for the measured payload accounting).
    """
    from jax.experimental.shard_map import shard_map

    axes = SH.data_axes(mesh)
    loss_fn = functools.partial(_loss, cfg=cfg, aux_weight=tcfg.aux_weight)

    def step(params, opt_state, err_state, batch):
        def shard_fn(params, opt_state, err_state, batch):
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            for ax in axes:
                grads, err_state = compression.compressed_psum(
                    grads, err_state, ax, enabled=compress
                )
            params2, opt2, om = adamw.apply_updates(
                params, grads, opt_state, tcfg.optimizer
            )
            metrics = {
                k: jax.lax.pmean(v, axes) for k, v in dict(metrics, **om).items()
            }
            return params2, opt2, err_state, metrics

        batch_spec = jax.tree.map(lambda _: P(axes, *([None])), batch)
        return shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(P(), P(), P(), batch_spec),
            out_specs=(P(), P(), P(), P()),
            check_rep=False,
        )(params, opt_state, err_state, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))
