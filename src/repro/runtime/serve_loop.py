"""Continuous-batching serving engine: slot-managed decode over the binary
Transformer datapath (what BETA does at the edge, scaled to a pod).

Components:

* ``make_prefill`` / ``make_decode_step`` — jitted SPMD steps over packed
  serving params + quantized KV caches (sharding per runtime.sharding).
  These are the functions the ``prefill_*`` / ``decode_*`` / ``long_*``
  dry-run cells lower.
* ``ServeEngine`` — host-side continuous-batching loop: an admission queue
  feeds a fixed-size packed decode batch.  Each slot carries its own request
  state (cache row with per-row position cursor + calibration affines,
  remaining-token budget, per-request RNG).  A newly admitted request is
  prefilled at its EXACT prompt length (batch 1, no padding) and spliced
  into a free slot with ``model_zoo.cache_insert`` while the other slots
  keep decoding; a finished slot is reset and immediately refilled from the
  queue — no wave ever stalls on its longest request.
* ``serve_sequential`` — the naive one-request-at-a-time oracle the
  differential tests compare against.

Numerical contract (what the differential test pins down): serve-mode
activation quantization is per-token and cache state is per-row, so a
request's tokens are bit-identical no matter which requests share the
batch — continuous batching is a pure scheduling optimization.

The decode step is the latency-critical path: one token per call against a
cache of ``max_len`` — its roofline is memory-bound, which is exactly where
the 1-bit packed weights + int8 KV cache pay off (EXPERIMENTS.md §Roofline).

With ``backend="auto"`` in the quant config, prefill and decode QMMs
(dense and attention projections; MoE expert MMs always use the MXU flow)
tune under separate autotune keys ("prefill" vs "decode" phases, set in
model_zoo) — their M dims differ by orders of magnitude, so the winning
backend can differ too.  Pass ``autotune_cache_path`` to ``ServeEngine`` to
persist/restore the measured verdicts across serving processes.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.models import model_zoo as Z
from repro.runtime import sharding as SH

__all__ = [
    "make_prefill",
    "make_decode_step",
    "ServeEngine",
    "Request",
    "serve_sequential",
]


def serving_params_shardings(cfg: ArchConfig, mesh: Mesh):
    tmpl = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        jax.random.PRNGKey(0),
    )
    return SH.params_shardings(tmpl, mesh), tmpl


def make_prefill(cfg: ArchConfig, mesh: Mesh, batch: int, prompt_len: int, max_len: int):
    p_sh, _ = serving_params_shardings(cfg, mesh)
    cache_tmpl = jax.eval_shape(lambda: Z.init_cache(batch, max_len, cfg))
    c_sh = SH.cache_shardings(cache_tmpl, mesh, batch)
    tok_sh = NamedSharding(mesh, SH.logical_batch_spec(batch, prompt_len, mesh))
    has_frontend = cfg.encoder is not None

    if has_frontend:

        def fn(params, tokens, cache, frontend):
            return Z.prefill(params, tokens, cfg, cache, frontend)

        in_sh = (p_sh, tok_sh, c_sh, None)
    else:

        def fn(params, tokens, cache):
            return Z.prefill(params, tokens, cfg, cache)

        in_sh = (p_sh, tok_sh, c_sh)

    return jax.jit(
        fn,
        in_shardings=in_sh,
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


def make_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    p_sh, _ = serving_params_shardings(cfg, mesh)
    cache_tmpl = jax.eval_shape(lambda: Z.init_cache(batch, max_len, cfg))
    c_sh = SH.cache_shardings(cache_tmpl, mesh, batch)

    def fn(params, tokens, cache):
        return Z.decode_step(params, tokens, cfg, cache)

    return jax.jit(
        fn,
        in_shardings=(p_sh, None, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# host-side engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # open-loop traffic: seconds (from run start) before the request exists
    arrival_s: float = 0.0
    # optional per-request streaming callback: on_token(token_id)
    on_token: Optional[Callable[[int], None]] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    rid: Optional[int] = None  # engine-assigned request id (RNG key)
    t_admitted: Optional[float] = None  # seconds from run start
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    token_times: Optional[List[float]] = None  # one stamp per output token


def _sample(logits: np.ndarray, temperature: float, rng: np.random.Generator) -> int:
    """Shared by the engine and the sequential oracle: greedy at T<=0,
    softmax sampling otherwise, against the request's OWN rng stream."""
    if temperature <= 0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / temperature
    z = z - z.max()
    p = np.exp(z)
    p = p / p.sum()
    return int(rng.choice(len(p), p=p))


def _request_rng(seed: int, rid: int) -> np.random.Generator:
    """Per-request stream keyed on (engine seed, request id): sampling is
    independent of which slot served the request and of its co-batch."""
    return np.random.default_rng([seed, rid])


@dataclasses.dataclass
class _Slot:
    req: Request
    remaining: int
    rng: np.random.Generator


class ServeEngine:
    """Slot-managed continuous batching.  Single-host driver; the jitted
    steps are SPMD so the same driver scales to a pod (per-slot prefill
    batches of 1 would be padded to the slot batch on real deployments).

    Scheduling loop per tick: (1) admit — while a slot is free and the
    head of the arrival-ordered queue has arrived, prefill it exactly
    (batch 1, its own prompt length) and ``cache_insert`` it into the free
    slot; (2) decode — one packed ``decode_step`` over all slots; active
    slots sample/stream their token, slots whose budget hits zero are
    ``cache_reset`` and freed for the next admission.  The event trace of
    the last ``run`` is kept on ``last_events`` for the slot-invariant
    property tests.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        autotune_cache_path: Optional[str] = None,
    ):
        """``autotune_cache_path``: optional JSON file for the QMM autotune
        cache (core.dispatch).  Loaded at engine start (a warm serving
        process skips backend re-timing entirely) and written back after
        each ``run`` so the next process inherits fresh verdicts.  Only
        meaningful when the arch's quant config uses ``backend="auto"``."""
        if cfg.encoder is not None and cfg.encoder.n_layers:
            raise NotImplementedError(
                "continuous batching drives decoder-only stacks; "
                "encoder-frontend archs go through make_prefill/make_decode_step"
            )
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.seed = seed
        self._next_rid = 0
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        self.mesh = mesh
        self.last_events: List[Dict] = []
        self.autotune_cache_path = autotune_cache_path
        if autotune_cache_path and os.path.exists(autotune_cache_path):
            dispatch.get_cache().load(autotune_cache_path)
        cfg_ = cfg

        def _decode(params, tokens, cache):
            return Z.decode_step(params, tokens, cfg_, cache)

        # fixed shapes: one compile per engine
        self._decode_fn = jax.jit(_decode)

    # -- internals ----------------------------------------------------------

    def _event(self, kind: str, t: float, **kw) -> None:
        self.last_events.append(dict(kind=kind, t=t, **kw))

    def _admit(self, req: Request, slot: int, cache: dict, now: float):
        """Exact-length batch-1 prefill + splice into ``slot``."""
        req.t_admitted = now
        self._event("admit", now, rid=req.rid, slot=slot, prompt_len=len(req.prompt))
        slot_cache = Z.init_slot_cache(self.max_len, self.cfg)
        tokens = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        logits, slot_cache = Z.prefill(self.params, tokens, self.cfg, slot_cache)
        self._event("prefill", time.perf_counter() - self._t0, rid=req.rid, slot=slot)
        cache = Z.cache_insert(cache, slot_cache, slot)
        self._event("insert", time.perf_counter() - self._t0, rid=req.rid, slot=slot)
        return np.asarray(logits)[0], cache

    def _emit(self, req: Request, token: int, now: float) -> None:
        req.output.append(token)
        req.token_times.append(now)
        if req.t_first_token is None:
            req.t_first_token = now
        if req.on_token is not None:
            req.on_token(token)

    # -- public API ---------------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a queue of requests; returns them in submission order.

        Requests with ``arrival_s > 0`` (open-loop traffic) are held back
        until their arrival time relative to the start of the call.
        """
        cfg = self.cfg
        for r in requests:
            plen = len(r.prompt)
            if plen < 1 or r.max_new_tokens < 1:
                raise ValueError("request needs a non-empty prompt and >= 1 new token")
            if plen + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"prompt_len({plen}) + max_new_tokens({r.max_new_tokens}) "
                    f"exceeds engine max_len({self.max_len})"
                )
        for r in requests:
            r.rid = self._next_rid
            self._next_rid += 1
            r.output = []
            r.token_times = []
            r.t_admitted = r.t_first_token = r.t_finished = None
        self.last_events = []
        self._t0 = time.perf_counter()
        clock = lambda: time.perf_counter() - self._t0

        queue = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        cache = Z.init_cache(self.slots, self.max_len, cfg)
        slots: List[Optional[_Slot]] = [None] * self.slots
        cur = np.zeros((self.slots,), np.int32)  # next decode input per slot

        def finish(i: int, now: float) -> None:
            nonlocal cache
            st = slots[i]
            st.req.t_finished = now
            self._event("finish", now, rid=st.req.rid, slot=i)
            cache = Z.cache_reset(cache, i, cfg, self.max_len)
            self._event("reset", clock(), rid=st.req.rid, slot=i)
            slots[i] = None

        while queue or any(s is not None for s in slots):
            # ---- admission: fill free slots from arrived requests --------
            while queue and queue[0].arrival_s <= clock() and None in slots:
                req = queue.pop(0)
                i = slots.index(None)
                logits, cache = self._admit(req, i, cache, clock())
                st = _Slot(req, req.max_new_tokens, _request_rng(self.seed, req.rid))
                tok = _sample(logits, req.temperature, st.rng)
                self._emit(req, tok, clock())
                st.remaining -= 1
                slots[i] = st
                cur[i] = tok
                if st.remaining == 0:
                    finish(i, clock())
            if all(s is None for s in slots):
                if queue:  # open-loop gap: idle until the next arrival
                    time.sleep(max(0.0, queue[0].arrival_s - clock()))
                continue

            # ---- one packed decode tick over every slot ------------------
            logits, cache = self._decode_fn(self.params, jnp.asarray(cur), cache)
            logits = np.asarray(logits)
            now = clock()
            self._event(
                "decode_tick",
                now,
                rids=[s.req.rid if s else None for s in slots],
            )
            for i, st in enumerate(slots):
                if st is None:
                    continue
                tok = _sample(logits[i], st.req.temperature, st.rng)
                self._emit(st.req, tok, now)
                st.remaining -= 1
                cur[i] = tok
                if st.remaining == 0:
                    finish(i, clock())

        if self.autotune_cache_path:
            dispatch.get_cache().save(self.autotune_cache_path)
        return list(requests)


def serve_sequential(
    cfg: ArchConfig,
    params,
    requests: List[Request],
    *,
    max_len: int = 256,
    seed: int = 0,
) -> List[Request]:
    """Naive one-request-at-a-time oracle: batch 1, no slot machinery, no
    co-batching — the reference the differential tests hold ``ServeEngine``
    to, token for token.  Shares ``_sample`` and the per-request RNG keying
    with the engine so sampling (not just greedy argmax) is comparable."""
    for rid, r in enumerate(requests):
        if len(r.prompt) + r.max_new_tokens > max_len:
            raise ValueError("request exceeds max_len")
        r.rid = rid
        rng = _request_rng(seed, rid)
        cache = Z.init_cache(1, max_len, cfg)
        tokens = jnp.asarray(np.asarray(r.prompt, np.int32)[None, :])
        logits, cache = Z.prefill(params, tokens, cfg, cache)
        tok = _sample(np.asarray(logits)[0], r.temperature, rng)
        r.output = [tok]
        while len(r.output) < r.max_new_tokens:
            logits, cache = Z.decode_step(
                params, jnp.asarray([tok], np.int32), cfg, cache
            )
            tok = _sample(np.asarray(logits)[0], r.temperature, rng)
            r.output.append(tok)
    return list(requests)
