"""Continuous-batching serving engine: slot-managed decode over the binary
Transformer datapath (what BETA does at the edge, scaled to a pod).

Components:

* ``make_prefill`` / ``make_decode_step`` — jitted SPMD steps over packed
  serving params + quantized KV caches (sharding per runtime.sharding).
  These are the functions the ``prefill_*`` / ``decode_*`` / ``long_*``
  dry-run cells lower.
* ``ServeEngine`` — host-side continuous-batching loop: an admission queue
  feeds a fixed-size packed decode batch.  Each slot carries its own request
  state (cache row with per-row position cursor + calibration affines,
  remaining-token budget, per-request RNG).  A newly admitted request is
  prefilled at its EXACT prompt length (batch 1, no padding) and spliced
  into a free slot with ``model_zoo.cache_insert`` while the other slots
  keep decoding; a finished slot is reset and immediately refilled from the
  queue — no wave ever stalls on its longest request.
* ``serve_sequential`` — the naive one-request-at-a-time oracle the
  differential tests compare against.

Numerical contract (what the differential test pins down): serve-mode
activation quantization is per-token and cache state is per-row, so a
request's tokens are bit-identical no matter which requests share the
batch — continuous batching is a pure scheduling optimization.

The decode step is the latency-critical path: one token per call against a
cache of ``max_len`` — its roofline is memory-bound, which is exactly where
the 1-bit packed weights + int8 KV cache pay off (EXPERIMENTS.md §Roofline).

With ``backend="auto"`` in the quant config, prefill and decode QMMs
(dense and attention projections; MoE expert MMs always use the MXU flow)
tune under separate autotune keys ("prefill" vs "decode" phases, set in
model_zoo) — their M dims differ by orders of magnitude, so the winning
backend can differ too.  Pass ``autotune_cache_path`` to ``ServeEngine`` to
persist/restore the measured verdicts across serving processes.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.models import model_zoo as Z
from repro.runtime import sharding as SH

__all__ = [
    "make_prefill",
    "make_decode_step",
    "ServeEngine",
    "Request",
    "serve_sequential",
    "STATE_PENDING",
    "STATE_OK",
    "STATE_FAILED",
    "STATE_DEADLINE",
    "TERMINAL_STATES",
]


def serving_params_shardings(cfg: ArchConfig, mesh: Mesh):
    tmpl = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        jax.random.PRNGKey(0),
    )
    return SH.params_shardings(tmpl, mesh), tmpl


def make_prefill(cfg: ArchConfig, mesh: Mesh, batch: int, prompt_len: int, max_len: int):
    p_sh, _ = serving_params_shardings(cfg, mesh)
    cache_tmpl = jax.eval_shape(lambda: Z.init_cache(batch, max_len, cfg))
    c_sh = SH.cache_shardings(cache_tmpl, mesh, batch)
    tok_sh = NamedSharding(mesh, SH.logical_batch_spec(batch, prompt_len, mesh))
    has_frontend = cfg.encoder is not None

    if has_frontend:

        def fn(params, tokens, cache, frontend):
            return Z.prefill(params, tokens, cfg, cache, frontend)

        in_sh = (p_sh, tok_sh, c_sh, None)
    else:

        def fn(params, tokens, cache):
            return Z.prefill(params, tokens, cfg, cache)

        in_sh = (p_sh, tok_sh, c_sh)

    return jax.jit(
        fn,
        in_shardings=in_sh,
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


def make_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    p_sh, _ = serving_params_shardings(cfg, mesh)
    cache_tmpl = jax.eval_shape(lambda: Z.init_cache(batch, max_len, cfg))
    c_sh = SH.cache_shardings(cache_tmpl, mesh, batch)

    def fn(params, tokens, cache):
        return Z.decode_step(params, tokens, cfg, cache)

    return jax.jit(
        fn,
        in_shardings=(p_sh, None, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# host-side engine
# ---------------------------------------------------------------------------


#: Terminal request states (``Request.state``).
STATE_PENDING = "pending"
STATE_OK = "ok"
STATE_FAILED = "failed"
STATE_DEADLINE = "deadline"
TERMINAL_STATES = (STATE_OK, STATE_FAILED, STATE_DEADLINE)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # open-loop traffic: seconds (from run start) before the request exists
    arrival_s: float = 0.0
    # optional per-request deadline, seconds FROM ARRIVAL; None = no deadline.
    # A request past its deadline is terminated with state "deadline" —
    # whether still queued or mid-generation — instead of holding a slot.
    deadline_s: Optional[float] = None
    # optional per-request streaming callback: on_token(token_id).  On a
    # retry (re-admission after a failure) the replayed tokens stream again
    # — consumers that must not double-deliver should key on Request.retries.
    on_token: Optional[Callable[[int], None]] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    rid: Optional[int] = None  # engine-assigned request id (RNG key)
    state: str = STATE_PENDING  # -> "ok" | "failed" | "deadline"
    retries: int = 0  # re-admissions after failures (NaN logits, step faults)
    t_admitted: Optional[float] = None  # seconds from run start
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    token_times: Optional[List[float]] = None  # one stamp per output token


def _sample(logits: np.ndarray, temperature: float, rng: np.random.Generator) -> int:
    """Shared by the engine and the sequential oracle: greedy at T<=0,
    softmax sampling otherwise, against the request's OWN rng stream."""
    if temperature <= 0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / temperature
    z = z - z.max()
    p = np.exp(z)
    p = p / p.sum()
    return int(rng.choice(len(p), p=p))


def _request_rng(seed: int, rid: int) -> np.random.Generator:
    """Per-request stream keyed on (engine seed, request id): sampling is
    independent of which slot served the request and of its co-batch."""
    return np.random.default_rng([seed, rid])


@dataclasses.dataclass
class _Slot:
    req: Request
    remaining: int
    rng: np.random.Generator


@dataclasses.dataclass
class _EngineState:
    """Everything ``_serve`` advances — and exactly what a snapshot captures.

    ``requests`` is the full set in rid order; ``queue`` and ``slots`` hold
    references into it.  ``tick`` counts *successful* decode ticks (a retried
    tick does not advance it), ``snaps`` counts snapshot attempts.
    """

    requests: List[Request]
    queue: List[Request]
    slots: List[Optional[_Slot]]
    cache: dict
    cur: np.ndarray
    tick: int = 0
    snaps: int = 0


def _pack_rng_state(rng: np.random.Generator) -> Dict:
    """PCG64 state as msgpack-able strings (the 128-bit ints overflow)."""
    st = rng.bit_generator.state
    return {
        "bit_generator": st["bit_generator"],
        "state": str(st["state"]["state"]),
        "inc": str(st["state"]["inc"]),
        "has_uint32": int(st["has_uint32"]),
        "uinteger": int(st["uinteger"]),
    }


def _unpack_rng_state(d: Dict) -> np.random.Generator:
    rng = np.random.default_rng(0)
    rng.bit_generator.state = {
        "bit_generator": d["bit_generator"],
        "state": {"state": int(d["state"]), "inc": int(d["inc"])},
        "has_uint32": int(d["has_uint32"]),
        "uinteger": int(d["uinteger"]),
    }
    return rng


class ServeEngine:
    """Slot-managed continuous batching with a fault-tolerant control loop.

    Single-host driver; the jitted steps are SPMD so the same driver scales
    to a pod (per-slot prefill batches of 1 would be padded to the slot
    batch on real deployments).

    Scheduling loop per tick: (1) expire — queued or running requests past
    their ``deadline_s`` are terminated with state "deadline"; (2) admit —
    while a slot is free and the head of the arrival-ordered queue has
    arrived, prefill it exactly (batch 1, its own prompt length) and
    ``cache_insert`` it into the free slot; (3) decode — one packed
    ``decode_step`` over all slots; active slots sample/stream their token,
    slots whose budget hits zero are ``cache_reset`` and freed for the next
    admission; (4) snapshot — every ``snapshot_every`` ticks the whole
    engine state goes through ``CheckpointManager`` so :meth:`resume` can
    finish the run after a crash.

    Failure policy (the treat-failure-as-input contract):

    * A failed decode *tick* is retried in place with exponential backoff,
      up to ``max_retries`` attempts — the decode step is functional (the
      jitted fn does not donate its cache), so a retry recomputes the
      identical logits.
    * A :class:`~repro.runtime.faults.BackendFault` counts against the named
      backend; ``demote_after`` failures pin a process-wide dispatch
      demotion (``dispatch.pin_demotion``, e.g. fused -> mxu), rebuild the
      jitted decode fn, and keep serving — the demotion is visible in
      ``last_events`` as a ``demote`` event.
    * Non-finite logits fail the ONE request in that row, never the engine:
      the request is re-admitted from its prompt under the same
      ``(seed, rid)`` RNG key, so its replayed token sequence is bit-identical
      to an unfailed run.  ``max_retries`` re-admissions later it is
      terminally "failed".
    * A failed snapshot write is an event, not an outage: the engine keeps
      serving and tries again at the next boundary.

    The event trace of the last ``run``/``resume`` is kept on
    ``last_events`` (kinds: admit/prefill/insert/decode_tick/finish/reset
    plus step_fault/retry_tick/backend_fault/demote/nan_logits/requeue/
    request_failed/prefill_fault/deadline_miss/snapshot/snapshot_failed/
    resume).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        autotune_cache_path: Optional[str] = None,
        fault_plan=None,
        max_retries: int = 2,
        retry_backoff_s: float = 0.005,
        demote_after: int = 2,
        demote_to: str = dispatch.DEFAULT_BACKEND,
        snapshot_every: int = 0,
        snapshot_dir: Optional[str] = None,
    ):
        """``autotune_cache_path``: optional JSON file for the QMM autotune
        cache (core.dispatch).  Loaded at engine start (a warm serving
        process skips backend re-timing entirely) and written back after
        each ``run`` so the next process inherits fresh verdicts.  Only
        meaningful when the arch's quant config uses ``backend="auto"``.

        ``fault_plan``: a :class:`~repro.runtime.faults.FaultPlan` (or a
        JSON string/dict for one) of deterministic injected failures; None
        is the no-op default.  ``max_retries`` bounds both in-place tick
        retries and per-request re-admissions; ``retry_backoff_s`` is the
        base of the exponential backoff between tick retries.
        ``demote_after`` failures of one backend pin it to ``demote_to``.
        ``snapshot_every`` > 0 checkpoints engine state to ``snapshot_dir``
        at that tick cadence (required for :meth:`resume`)."""
        if cfg.encoder is not None and cfg.encoder.n_layers:
            raise NotImplementedError(
                "continuous batching drives decoder-only stacks; "
                "encoder-frontend archs go through make_prefill/make_decode_step"
            )
        from repro.runtime.faults import parse_fault_plan

        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.seed = seed
        self._next_rid = 0
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        self.mesh = mesh
        self.last_events: List[Dict] = []
        self.autotune_cache_path = autotune_cache_path
        if autotune_cache_path and os.path.exists(autotune_cache_path):
            dispatch.get_cache().load(autotune_cache_path)
        self.fault_plan = parse_fault_plan(fault_plan)
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.demote_after = demote_after
        self.demote_to = demote_to
        self.snapshot_every = snapshot_every
        self.snapshot_dir = snapshot_dir
        self._backend_failures: Dict[str, int] = {}
        self._demoted: Dict[str, str] = {}
        self._decode_fn = self._make_decode()

    def _make_decode(self):
        cfg_ = self.cfg

        def _decode(params, tokens, cache):
            return Z.decode_step(params, tokens, cfg_, cache)

        # fixed shapes: one compile per wrapper.  Rebuilt after a backend
        # demotion — the dispatch choice is baked in at trace time, so a
        # fresh jit wrapper is what makes the demotion take effect.
        return jax.jit(_decode)

    # -- internals ----------------------------------------------------------

    def _event(self, kind: str, t: float, **kw) -> None:
        self.last_events.append(dict(kind=kind, t=t, **kw))

    def _admit(self, req: Request, slot: int, cache: dict, now: float):
        """Exact-length batch-1 prefill + splice into ``slot``."""
        req.t_admitted = now
        self._event("admit", now, rid=req.rid, slot=slot, prompt_len=len(req.prompt))
        slot_cache = Z.init_slot_cache(self.max_len, self.cfg)
        tokens = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
        logits, slot_cache = Z.prefill(self.params, tokens, self.cfg, slot_cache)
        self._event("prefill", time.perf_counter() - self._t0, rid=req.rid, slot=slot)
        cache = Z.cache_insert(cache, slot_cache, slot)
        self._event("insert", time.perf_counter() - self._t0, rid=req.rid, slot=slot)
        return np.asarray(logits)[0], cache

    def _emit(self, req: Request, token: int, now: float) -> None:
        req.output.append(token)
        req.token_times.append(now)
        if req.t_first_token is None:
            req.t_first_token = now
        if req.on_token is not None:
            req.on_token(token)

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    @staticmethod
    def _expired(req: Request, now: float) -> bool:
        return req.deadline_s is not None and now - req.arrival_s > req.deadline_s

    @staticmethod
    def _reset_progress(req: Request) -> None:
        """Rewind a request to its prompt (re-admission replays from here)."""
        req.output = []
        req.token_times = []
        req.t_admitted = req.t_first_token = req.t_finished = None

    def _requeue(self, st: _EngineState, req: Request, slot: Optional[int]) -> None:
        """Re-admit ``req`` after a failure — or terminally fail it.

        The slot (if held) is reset so co-batched requests are untouched.
        Replay is bit-identical to an unfailed run: progress rewinds to the
        prompt and the RNG is re-derived from the same ``(seed, rid)`` key
        at the next admission.
        """
        now = self._clock()
        if slot is not None and st.slots[slot] is not None:
            st.cache = Z.cache_reset(st.cache, slot, self.cfg, self.max_len)
            self._event("reset", self._clock(), rid=req.rid, slot=slot)
            st.slots[slot] = None
        req.retries += 1
        if req.retries > self.max_retries:
            req.state = STATE_FAILED
            req.t_finished = now
            self._event("request_failed", now, rid=req.rid, retries=req.retries)
            return
        self._reset_progress(req)
        st.queue.insert(0, req)
        self._event("requeue", now, rid=req.rid, retries=req.retries)

    def _finish(self, st: _EngineState, i: int, now: float, state: str = STATE_OK) -> None:
        slot = st.slots[i]
        slot.req.state = state
        slot.req.t_finished = now
        kind = "finish" if state == STATE_OK else "deadline_miss"
        self._event(kind, now, rid=slot.req.rid, slot=i)
        st.cache = Z.cache_reset(st.cache, i, self.cfg, self.max_len)
        self._event("reset", self._clock(), rid=slot.req.rid, slot=i)
        st.slots[i] = None

    def _note_backend_failure(self, backend: str, now: float) -> None:
        """Count a backend-attributed failure; demote the repeat offender."""
        n = self._backend_failures.get(backend, 0) + 1
        self._backend_failures[backend] = n
        self._event("backend_fault", now, backend=backend, count=n)
        if n < self.demote_after or backend in self._demoted:
            return
        target = self.demote_to if self.demote_to != backend else dispatch.DEFAULT_BACKEND
        dispatch.pin_demotion(backend, target)
        self._demoted[backend] = target
        # the demoted backend may be baked into the compiled decode step;
        # a fresh jit wrapper re-resolves dispatch at its next trace
        self._decode_fn = self._make_decode()
        self._event("demote", self._clock(), **{"from": backend, "to": target})

    # -- public API ---------------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a queue of requests; returns them in submission order.

        Requests with ``arrival_s > 0`` (open-loop traffic) are held back
        until their arrival time relative to the start of the call.  Every
        returned request carries a terminal ``state``: "ok" (full output),
        "deadline" (expired before completing), or "failed" (exceeded the
        retry budget after repeated faults).
        """
        for r in requests:
            prompt = np.asarray(r.prompt)
            if prompt.ndim != 1:
                raise ValueError(f"prompt must be rank-1, got shape {prompt.shape}")
            plen = len(prompt)
            if plen < 1 or r.max_new_tokens < 1:
                raise ValueError("request needs a non-empty prompt and >= 1 new token")
            if plen + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"prompt_len({plen}) + max_new_tokens({r.max_new_tokens}) "
                    f"exceeds engine max_len({self.max_len})"
                )
            if r.deadline_s is not None and r.deadline_s <= 0:
                raise ValueError(f"deadline_s must be positive, got {r.deadline_s}")
        for r in requests:
            r.rid = self._next_rid
            self._next_rid += 1
            r.state = STATE_PENDING
            r.retries = 0
            self._reset_progress(r)
        self.last_events = []
        self._t0 = time.perf_counter()

        state = _EngineState(
            requests=list(requests),
            queue=sorted(requests, key=lambda r: (r.arrival_s, r.rid)),
            slots=[None] * self.slots,
            cache=Z.init_cache(self.slots, self.max_len, self.cfg),
            cur=np.zeros((self.slots,), np.int32),
        )
        self._serve(state)
        return list(requests)

    def _serve(self, st: _EngineState) -> None:
        """Drive ``st`` to completion (shared by :meth:`run` and
        :meth:`resume`); every fault-policy decision lives here."""
        from repro.runtime.faults import BackendFault, FaultInjector

        inj = FaultInjector(self.fault_plan)
        clock = self._clock

        while st.queue or any(s is not None for s in st.slots):
            # ---- deadline sweep over the waiting queue -------------------
            now = clock()
            for req in [r for r in st.queue if self._expired(r, now)]:
                st.queue.remove(req)
                req.state = STATE_DEADLINE
                req.t_finished = now
                self._event("deadline_miss", now, rid=req.rid, slot=None)

            # ---- admission: fill free slots from arrived requests --------
            while st.queue and st.queue[0].arrival_s <= clock() and None in st.slots:
                req = st.queue.pop(0)
                i = st.slots.index(None)
                try:
                    inj.before_prefill(req.rid)
                    logits, st.cache = self._admit(req, i, st.cache, clock())
                except Exception as e:  # noqa: BLE001 — contained per-request
                    self._event(
                        "prefill_fault", clock(), rid=req.rid, error=repr(e)
                    )
                    self._requeue(st, req, slot=None)
                    continue
                if not np.all(np.isfinite(logits)):
                    self._event("nan_logits", clock(), rid=req.rid, slot=i)
                    self._requeue(st, req, slot=None)
                    continue
                slot = _Slot(req, req.max_new_tokens, _request_rng(self.seed, req.rid))
                tok = _sample(logits, req.temperature, slot.rng)
                self._emit(req, tok, clock())
                slot.remaining -= 1
                st.slots[i] = slot
                st.cur[i] = tok
                if slot.remaining == 0:
                    self._finish(st, i, clock())
            if all(s is None for s in st.slots):
                if st.queue:  # open-loop gap: idle until the next arrival
                    time.sleep(max(0.0, st.queue[0].arrival_s - clock()))
                continue

            # ---- one packed decode tick over every slot ------------------
            # Retried in place on failure: the jitted step does not donate
            # its cache, so a retry sees identical inputs -> identical
            # logits.  A BackendFault resets the attempt budget after a
            # demotion (the engine changed configuration; the next attempt
            # is a different program).
            logits = None
            attempt = 0
            while True:
                try:
                    inj.before_decode(st.tick, demoted=self._demoted)
                    out, new_cache = self._decode_fn(
                        self.params, jnp.asarray(st.cur), st.cache
                    )
                    logits = inj.corrupt_logits(st.tick, np.asarray(out))
                    break
                except BackendFault as e:
                    demoted_before = dict(self._demoted)
                    self._note_backend_failure(e.backend, clock())
                    if self._demoted != demoted_before:
                        attempt = 0
                        continue
                    attempt += 1
                except Exception as e:  # noqa: BLE001 — step faults retried
                    self._event(
                        "step_fault", clock(), tick=st.tick, error=repr(e)
                    )
                    attempt += 1
                if attempt > self.max_retries:
                    break
                backoff = self.retry_backoff_s * (2 ** (attempt - 1))
                self._event(
                    "retry_tick", clock(), tick=st.tick, attempt=attempt,
                    backoff_s=backoff,
                )
                if backoff > 0:
                    time.sleep(backoff)
            if logits is None:
                # tick retry budget exhausted: the batch is lost, the
                # requests are not — each replays from its prompt (or fails
                # terminally once ITS budget is gone).  The engine survives.
                for i in range(self.slots):
                    if st.slots[i] is not None:
                        self._requeue(st, st.slots[i].req, slot=i)
                continue
            st.cache = new_cache
            st.tick += 1
            now = clock()
            self._event(
                "decode_tick",
                now,
                rids=[s.req.rid if s else None for s in st.slots],
            )
            for i, slot in enumerate(st.slots):
                if slot is None:
                    continue
                row = logits[i]
                if not np.all(np.isfinite(row)):
                    # contain the numerics escape to this one request
                    self._event("nan_logits", now, rid=slot.req.rid, slot=i)
                    self._requeue(st, slot.req, slot=i)
                    continue
                tok = _sample(row, slot.req.temperature, slot.rng)
                self._emit(slot.req, tok, now)
                slot.remaining -= 1
                st.cur[i] = tok
                if slot.remaining == 0:
                    self._finish(st, i, clock())

            # ---- deadline sweep over running slots -----------------------
            now = clock()
            for i in range(self.slots):
                if st.slots[i] is not None and self._expired(st.slots[i].req, now):
                    self._finish(st, i, now, state=STATE_DEADLINE)

            # ---- periodic crash-recovery snapshot ------------------------
            if self.snapshot_every and st.tick % self.snapshot_every == 0:
                try:
                    inj.on_snapshot(st.snaps)
                    self._snapshot(st)
                    self._event("snapshot", clock(), tick=st.tick, ordinal=st.snaps)
                except Exception as e:  # noqa: BLE001 — snapshots are best-effort
                    self._event(
                        "snapshot_failed", clock(), tick=st.tick,
                        ordinal=st.snaps, error=repr(e),
                    )
                st.snaps += 1

        if self.autotune_cache_path:
            dispatch.get_cache().save(self.autotune_cache_path)

    # -- crash-recoverable engine state -------------------------------------

    def _snapshot_manager(self):
        from repro.checkpoint import CheckpointManager

        if not self.snapshot_dir:
            raise ValueError("snapshot_dir is not configured on this engine")
        return CheckpointManager(self.snapshot_dir, keep=2)

    def _snapshot(self, st: _EngineState) -> None:
        """Persist the full engine state through ``CheckpointManager``.

        Arrays (the packed decode cache + per-slot next-token inputs) go in
        the checkpoint tree; the host-side scheduler state (queue order,
        per-slot budgets, per-request progress and PCG64 sampler states)
        rides in the manifest extras.  Committed atomically — a crash
        mid-write leaves the previous snapshot restorable.
        """
        mgr = self._snapshot_manager()
        tree = {"cache": st.cache, "cur": jnp.asarray(st.cur)}
        extras = {
            "serve": {
                "arch": self.cfg.name,
                "seed": int(self.seed),
                "batch_slots": int(self.slots),
                "max_len": int(self.max_len),
                "tick": int(st.tick),
                "snaps": int(st.snaps),
                "next_rid": int(self._next_rid),
                "elapsed_s": float(self._clock()),
                "queue_rids": [int(r.rid) for r in st.queue],
                "slots": [
                    None
                    if s is None
                    else {
                        "rid": int(s.req.rid),
                        "remaining": int(s.remaining),
                        "rng": _pack_rng_state(s.rng),
                    }
                    for s in st.slots
                ],
                "requests": [
                    {
                        "rid": int(r.rid),
                        "prompt": [int(t) for t in np.asarray(r.prompt)],
                        "max_new_tokens": int(r.max_new_tokens),
                        "temperature": float(r.temperature),
                        "arrival_s": float(r.arrival_s),
                        "deadline_s": None if r.deadline_s is None else float(r.deadline_s),
                        "state": r.state,
                        "retries": int(r.retries),
                        "output": [int(t) for t in (r.output or [])],
                        "token_times": [float(t) for t in (r.token_times or [])],
                    }
                    for r in st.requests
                ],
            }
        }
        mgr.save(st.tick, tree, extras)

    def resume(self) -> List[Request]:
        """Finish the run recorded in ``snapshot_dir``'s latest snapshot.

        Reconstructs the admission queue, per-slot caches/cursors/budgets
        and sampler states, then drives the normal serve loop to completion
        — the surviving requests' outputs are token-for-token identical to
        an uninterrupted run (the decode cache rows, next-token inputs, and
        PCG64 states are restored exactly).  Returns every request of the
        original run, in rid order, including those that had already
        finished before the snapshot.
        """
        from repro.checkpoint import manager as CM

        mgr = self._snapshot_manager()
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed snapshot in {self.snapshot_dir}")
        # geometry check against the manifest BEFORE materializing arrays:
        # a mismatched engine gets the actionable error, not a shape trace
        manifest = CM._read_manifest(
            os.path.join(self.snapshot_dir, f"step_{step:09d}")
        )
        s = manifest["extras"]["serve"]
        if s["arch"] != self.cfg.name or s["batch_slots"] != self.slots or s[
            "max_len"
        ] != self.max_len:
            raise ValueError(
                f"snapshot geometry mismatch: snapshot is {s['arch']} "
                f"slots={s['batch_slots']} max_len={s['max_len']}, engine is "
                f"{self.cfg.name} slots={self.slots} max_len={self.max_len}"
            )
        like = {
            "cache": Z.init_cache(self.slots, self.max_len, self.cfg),
            "cur": jnp.zeros((self.slots,), jnp.int32),
        }
        step, tree, extras = mgr.restore(step, like=like)
        s = extras["serve"]

        by_rid: Dict[int, Request] = {}
        for rec in s["requests"]:
            req = Request(
                prompt=np.asarray(rec["prompt"], np.int32),
                max_new_tokens=rec["max_new_tokens"],
                temperature=rec["temperature"],
                arrival_s=rec["arrival_s"],
                deadline_s=rec["deadline_s"],
            )
            req.rid = rec["rid"]
            req.state = rec["state"]
            req.retries = rec["retries"]
            req.output = list(rec["output"])
            req.token_times = list(rec["token_times"])
            if req.token_times:
                req.t_first_token = req.token_times[0]
            by_rid[req.rid] = req

        slots: List[Optional[_Slot]] = []
        for rec in s["slots"]:
            if rec is None:
                slots.append(None)
            else:
                slots.append(
                    _Slot(
                        by_rid[rec["rid"]],
                        rec["remaining"],
                        _unpack_rng_state(rec["rng"]),
                    )
                )
        state = _EngineState(
            requests=[by_rid[r] for r in sorted(by_rid)],
            queue=[by_rid[r] for r in s["queue_rids"]],
            slots=slots,
            cache=tree["cache"],
            cur=np.asarray(tree["cur"], np.int32).copy(),
            tick=s["tick"],
            snaps=s["snaps"],
        )
        self._next_rid = max(self._next_rid, s["next_rid"])
        self.last_events = []
        # continue the run's clock where it stopped, so arrival offsets and
        # deadlines keep their meaning across the restart
        self._t0 = time.perf_counter() - s["elapsed_s"]
        self._event("resume", self._clock(), tick=state.tick, step=step)
        self._serve(state)
        return state.requests


def serve_sequential(
    cfg: ArchConfig,
    params,
    requests: List[Request],
    *,
    max_len: int = 256,
    seed: int = 0,
) -> List[Request]:
    """Naive one-request-at-a-time oracle: batch 1, no slot machinery, no
    co-batching — the reference the differential tests hold ``ServeEngine``
    to, token for token.  Shares ``_sample`` and the per-request RNG keying
    with the engine so sampling (not just greedy argmax) is comparable.
    Fault-free and deadline-blind by construction — it defines the token
    sequences the fault-tolerant engine must reproduce."""
    for rid, r in enumerate(requests):
        if len(r.prompt) + r.max_new_tokens > max_len:
            raise ValueError("request exceeds max_len")
        r.rid = rid
        rng = _request_rng(seed, rid)
        cache = Z.init_cache(1, max_len, cfg)
        tokens = jnp.asarray(np.asarray(r.prompt, np.int32)[None, :])
        logits, cache = Z.prefill(params, tokens, cfg, cache)
        tok = _sample(np.asarray(logits)[0], r.temperature, rng)
        r.output = [tok]
        while len(r.output) < r.max_new_tokens:
            logits, cache = Z.decode_step(
                params, jnp.asarray([tok], np.int32), cfg, cache
            )
            tok = _sample(np.asarray(logits)[0], r.temperature, rng)
            r.output.append(tok)
        r.state = STATE_OK
    return list(requests)
