"""Batched serving engine: slot-based continuous batching over the binary
Transformer datapath (what BETA does at the edge, scaled to a pod).

Components:

* ``make_prefill`` / ``make_decode_step`` — jitted SPMD steps over packed
  serving params + quantized KV caches (sharding per runtime.sharding).
  These are the functions the ``prefill_*`` / ``decode_*`` / ``long_*``
  dry-run cells lower.
* ``ServeEngine`` — host-side request loop: fixed batch slots, each slot
  independently prefilled/reset (continuous batching without dynamic
  shapes: a finished slot is re-prefilled for the next queued request while
  other slots keep decoding).  Greedy or temperature sampling.

The decode step is the latency-critical path: one token per call against a
cache of ``max_len`` — its roofline is memory-bound, which is exactly where
the 1-bit packed weights + int8 KV cache pay off (EXPERIMENTS.md §Roofline).

With ``backend="auto"`` in the quant config, prefill and decode QMMs
(dense and attention projections; MoE expert MMs always use the MXU flow)
tune under separate autotune keys ("prefill" vs "decode" phases, set in
model_zoo) — their M dims differ by orders of magnitude, so the winning
backend can differ too.  Pass ``autotune_cache_path`` to ``ServeEngine`` to
persist/restore the measured verdicts across serving processes.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.models import model_zoo as Z
from repro.runtime import sharding as SH

__all__ = ["make_prefill", "make_decode_step", "ServeEngine", "Request"]


def serving_params_shardings(cfg: ArchConfig, mesh: Mesh):
    tmpl = jax.eval_shape(
        lambda k: Z.prepare_serving_params(Z.init_params(k, cfg), cfg),
        jax.random.PRNGKey(0),
    )
    return SH.params_shardings(tmpl, mesh), tmpl


def make_prefill(cfg: ArchConfig, mesh: Mesh, batch: int, prompt_len: int, max_len: int):
    p_sh, _ = serving_params_shardings(cfg, mesh)
    cache_tmpl = jax.eval_shape(lambda: Z.init_cache(batch, max_len, cfg))
    c_sh = SH.cache_shardings(cache_tmpl, mesh, batch)
    tok_sh = NamedSharding(mesh, SH.logical_batch_spec(batch, prompt_len, mesh))
    has_frontend = cfg.encoder is not None

    if has_frontend:

        def fn(params, tokens, cache, frontend):
            return Z.prefill(params, tokens, cfg, cache, frontend)

        in_sh = (p_sh, tok_sh, c_sh, None)
    else:

        def fn(params, tokens, cache):
            return Z.prefill(params, tokens, cfg, cache)

        in_sh = (p_sh, tok_sh, c_sh)

    return jax.jit(
        fn,
        in_shardings=in_sh,
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


def make_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    p_sh, _ = serving_params_shardings(cfg, mesh)
    cache_tmpl = jax.eval_shape(lambda: Z.init_cache(batch, max_len, cfg))
    c_sh = SH.cache_shardings(cache_tmpl, mesh, batch)

    def fn(params, tokens, cache):
        return Z.decode_step(params, tokens, cfg, cache)

    return jax.jit(
        fn,
        in_shardings=(p_sh, None, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# host-side engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    output: Optional[List[int]] = None


class ServeEngine:
    """Fixed-slot batched serving. Single-host driver; the jitted steps are
    SPMD so the same driver scales to a pod (per-slot prefill batches of 1
    would be padded to the slot batch on real deployments)."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        autotune_cache_path: Optional[str] = None,
    ):
        """``autotune_cache_path``: optional JSON file for the QMM autotune
        cache (core.dispatch).  Loaded at engine start (a warm serving
        process skips backend re-timing entirely) and written back after
        each ``run`` so the next process inherits fresh verdicts.  Only
        meaningful when the arch's quant config uses ``backend="auto"``."""
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        self.mesh = mesh
        self._decode = None  # built lazily per batch size
        self.autotune_cache_path = autotune_cache_path
        if autotune_cache_path and os.path.exists(autotune_cache_path):
            dispatch.get_cache().load(autotune_cache_path)

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        if temperature <= 0:
            return int(np.argmax(logits))
        z = logits / temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self.rng.choice(len(p), p=p))

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a queue of requests through ``slots`` parallel lanes."""
        cfg = self.cfg
        queue = list(requests)
        # process in waves of `slots`; each wave shares a prefill length
        done: List[Request] = []
        while queue:
            wave = queue[: self.slots]
            queue = queue[len(wave) :]
            plen = max(len(r.prompt) for r in wave)
            toks = np.zeros((len(wave), plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, plen - len(r.prompt) :] = r.prompt  # left-pad
            cache = Z.init_cache(len(wave), self.max_len, cfg)
            logits, cache = Z.prefill(self.params, jnp.asarray(toks), cfg, cache)
            logits = np.asarray(logits)
            cur = np.array(
                [self._sample(logits[i], r.temperature) for i, r in enumerate(wave)],
                np.int32,
            )
            outs = [[int(c)] for c in cur]
            steps = max(r.max_new_tokens for r in wave) - 1
            for _ in range(max(0, steps)):
                logits, cache = Z.decode_step(
                    self.params, jnp.asarray(cur), cfg, cache
                )
                logits = np.asarray(logits)
                cur = np.array(
                    [
                        self._sample(logits[i], r.temperature)
                        for i, r in enumerate(wave)
                    ],
                    np.int32,
                )
                for i, r in enumerate(wave):
                    if len(outs[i]) < r.max_new_tokens:
                        outs[i].append(int(cur[i]))
            for r, o in zip(wave, outs):
                r.output = o[: r.max_new_tokens]
                done.append(r)
        if self.autotune_cache_path:
            dispatch.get_cache().save(self.autotune_cache_path)
        return done
