from repro.runtime import fault_tolerance, serve_loop, sharding, train_loop

__all__ = ["fault_tolerance", "serve_loop", "sharding", "train_loop"]
