"""Model zoo: quant-aware transformer/SSM stacks for all assigned archs."""

from repro.models import attention, layers, model_zoo, moe, ssm, transformer

__all__ = ["attention", "layers", "model_zoo", "moe", "ssm", "transformer"]
