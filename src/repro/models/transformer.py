"""Block assembly: (mixer + FFN) blocks, scanned period stacks, enc-dec.

Stack layout (configs/base.py): ``prefix_layers`` are unrolled with their own
params; the repeating ``pattern_period`` is lowered as ONE ``lax.scan`` over
``n_periods`` with params (and caches) stacked on the leading axis per
period position.  HLO size therefore scales with ``len(period)``, not
``n_layers`` — essential for the 512-way SPMD dry-run compiles of 60+-layer
models on this 1-core container, and for real-world compile latency.

Pre-norm residual blocks throughout (RMSNorm; BERT-family's post-LN is
mapped to pre-norm — systems-equivalent, noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

__all__ = [
    "init_block",
    "block_apply",
    "init_block_cache",
    "init_stack",
    "stack_apply",
    "init_stack_cache",
]


def _needs_cross(cfg: ArchConfig) -> bool:
    return cfg.encoder is not None and cfg.encoder.n_layers > 0


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig, kind: str, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict = {"ln1": jnp.zeros((d,), jnp.float32)}
    if kind in ("g", "l"):
        p["attn"] = A.init_attention(ks[0], cfg)
    elif kind in ("Md", "Mm"):
        p["attn"] = A.init_mla(ks[0], cfg)
    elif kind == "r":
        p["rglru"] = S.init_rglru(ks[0], cfg)
    elif kind == "s":
        p["ssd"] = S.init_ssd(ks[0], cfg)
        return p  # mamba2 block = norm + mixer only
    else:
        raise ValueError(f"unknown block kind {kind!r}")

    if cross:
        p["ln_cross"] = jnp.zeros((d,), jnp.float32)
        p["cross_attn"] = A.init_attention(ks[2], cfg)

    p["ln2"] = jnp.zeros((d,), jnp.float32)
    if kind == "Mm":
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        ff = cfg.d_ff
        p["ffn"] = L.init_ffn(ks[1], cfg.ffn_type, d, ff)
    return p


def init_block_cache(batch: int, max_len: int, cfg: ArchConfig, kind: str):
    if kind in ("g", "l"):
        return A.init_kv_cache(batch, max_len, cfg, kind)
    if kind in ("Md", "Mm"):
        return A.init_mla_cache(batch, max_len, cfg)
    if kind == "r":
        return S.init_rglru_state(batch, cfg)
    if kind == "s":
        return S.init_ssd_state(batch, cfg)
    raise ValueError(kind)


def block_apply(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    kind: str,
    mode: str,
    positions: jax.Array,
    cache=None,
    encoder_out: Optional[jax.Array] = None,
):
    """Pre-norm residual block. Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("g", "l"):
        mix, cache = A.attention(p["attn"], h, cfg, kind, mode, positions, cache)
    elif kind in ("Md", "Mm"):
        mix, cache = A.mla_attention(p["attn"], h, cfg, mode, positions, cache)
    elif kind == "r":
        mix, cache = S.rglru_mixer(p["rglru"], h, cfg, mode, cache)
    elif kind == "s":
        mix, cache = S.ssd_mixer(p["ssd"], h, cfg, mode, cache)
        return x + mix, cache, aux
    x = x + mix

    if "cross_attn" in p and encoder_out is not None:
        h = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        kvh, dh = cfg.n_kv_heads, cfg.d_head
        ck = L.qlinear(
            p["cross_attn"]["k"], encoder_out, cfg.quant, mode, name="cross_attn.k"
        )
        cv = L.qlinear(
            p["cross_attn"]["v"], encoder_out, cfg.quant, mode, name="cross_attn.v"
        )
        ck = ck.reshape(*encoder_out.shape[:-1], kvh, dh)
        cv = cv.reshape(*encoder_out.shape[:-1], kvh, dh)
        mix, _ = A.attention(
            p["cross_attn"], h, cfg, "g", mode, positions,
            kv_override=(ck, cv), causal=False,
        )
        x = x + mix

    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "Mm":
        out, aux = M.moe_ffn(p["moe"], h, cfg, mode)
    else:
        out = L.ffn(p["ffn"], h, cfg.ffn_type, cfg.quant, mode)
    return x + out, cache, aux


# ---------------------------------------------------------------------------
# the stack: prefix (unrolled) + period (scanned)
# ---------------------------------------------------------------------------


def init_stack(key, cfg: ArchConfig, cross: bool = False) -> dict:
    """Params: {'prefix': [block...], 'period': [stacked-block...]}.

    Period params are stacked along axis 0 with length ``n_periods`` (one
    entry per scan step), independently for each position in the period.
    """
    keys = jax.random.split(key, len(cfg.prefix_layers) + 1)
    prefix = [
        init_block(keys[i], cfg, kind, cross)
        for i, kind in enumerate(cfg.prefix_layers)
    ]
    period = []
    if cfg.n_periods:
        pkeys = jax.random.split(keys[-1], len(cfg.pattern_period))
        for j, kind in enumerate(cfg.pattern_period):
            reps = jax.random.split(pkeys[j], cfg.n_periods)
            stacked = jax.vmap(lambda k: init_block(k, cfg, kind, cross))(reps)
            period.append(stacked)
    return {"prefix": prefix, "period": period}


def init_stack_cache(batch: int, max_len: int, cfg: ArchConfig) -> dict:
    prefix = [
        init_block_cache(batch, max_len, cfg, kind) for kind in cfg.prefix_layers
    ]
    period = []
    for kind in cfg.pattern_period:
        one = init_block_cache(batch, max_len, cfg, kind)
        period.append(
            jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), one)
        )
    return {"prefix": prefix, "period": period}


def stack_apply(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    mode: str,
    positions: jax.Array,
    caches: Optional[dict] = None,
    encoder_out: Optional[jax.Array] = None,
):
    """Apply prefix blocks then the scanned period stack.

    Returns (x, new_caches, aux_total).
    """
    aux_total = jnp.float32(0.0)
    new_prefix = []
    for i, kind in enumerate(cfg.prefix_layers):
        c = caches["prefix"][i] if caches is not None else None
        x, c, aux = block_apply(
            params["prefix"][i], x, cfg, kind, mode, positions, c, encoder_out
        )
        new_prefix.append(c)
        aux_total += aux

    new_period = [None] * len(cfg.pattern_period)
    if cfg.n_periods:

        def body(carry, xs):
            xc, aux_c = carry
            p_stk = xs["params"]
            c_stk = xs.get("caches")
            new_cs = []
            for j, kind in enumerate(cfg.pattern_period):
                cj = c_stk[j] if c_stk is not None else None
                xc, cj, aux = block_apply(
                    p_stk[j], xc, cfg, kind, mode, positions, cj, encoder_out
                )
                new_cs.append(cj if cj is not None else 0)
                aux_c = aux_c + aux
            ys = {"caches": new_cs} if c_stk is not None else {}
            return (xc, aux_c), ys

        xs = {"params": params["period"]}
        if caches is not None:
            xs["caches"] = caches["period"]
        # Block-level remat for QAT training: recompute the period body on
        # the backward pass (activation memory ~ one period, not n_layers).
        scan_body = jax.checkpoint(body) if mode == "train" else body
        (x, aux_total), ys = jax.lax.scan(scan_body, (x, aux_total), xs)
        if caches is not None:
            new_period = ys["caches"]

    new_caches = None
    if caches is not None:
        new_caches = {"prefix": new_prefix, "period": new_period}
    return x, new_caches, aux_total
