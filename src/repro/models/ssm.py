"""Recurrent mixers: Mamba-2 SSD and RG-LRU (recurrentgemma) — quant-aware.

BETA applicability (DESIGN.md §5): the in/out/gate *projections* are
act x weight QMMs like any dense layer.  The recurrences themselves are
elementwise/linear-scan state updates — not QMMs — and stay full precision,
exactly as the paper keeps its non-QMM ops FP.  The chunked SSD form's
intra-chunk matmuls are act x act shaped; in serve mode they run fake-
quantized (beyond-paper extension, flagged in DESIGN.md) — the integer
engine applies but per-chunk affine bookkeeping dominates at these tiny
chunk sizes, so the win is recorded in §Perf napkin math, not claimed.

Both mixers expose (full-sequence, single-step) forms: training/prefill use
scan-free chunked math (SSD) or associative scan (RG-LRU); decode carries an
O(1) recurrent state — this is what makes mamba2/recurrentgemma the
long_500k-eligible architectures.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

__all__ = [
    "init_ssd",
    "ssd_mixer",
    "init_ssd_state",
    "init_rglru",
    "rglru_mixer",
    "init_rglru_state",
]


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


def init_ssd(key, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gz = s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [x (di), z-gate (di), B (gz), C (gz), dt (nh)]
        "in_proj": L.init_linear(ks[0], d, 2 * di + 2 * gz + nh),
        "out_proj": L.init_linear(ks[1], di, d, scale=0.5),
        "conv_w": jax.random.normal(ks[2], (s.d_conv, di + 2 * gz), jnp.float32) * 0.2,
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_g": jnp.zeros((di,), jnp.float32),  # gated RMSNorm pre out_proj
    }


def init_ssd_state(batch: int, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    nh = s.n_heads(cfg.d_model)
    di = s.d_inner(cfg.d_model)
    gz = s.n_groups * s.d_state
    return {
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * gz), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),  # per-row cursor (serving slots)
    }


def _segsum(a: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) lower-triangular segment sums:
    out[i, j] = sum_{j < k <= i} a[k]  (0 on the diagonal, -inf above)."""
    t = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def _ssd_chunked(x, dt, a_coef, b_mat, c_mat, chunk: int, init_state=None):
    """Chunked SSD (mamba2 'ssd_minimal' algorithm, matmul-rich form).

    Args:
      x: (B, S, H, P) inputs.
      dt: (B, S, H) positive step sizes.
      a_coef: (H,) negative decay coefficients.
      b_mat, c_mat: (B, S, G, N) input/output projections (G groups).
      chunk: chunk length Q (S % Q == 0; callers pad).
      init_state: optional (B, H, P, N) carried state.

    Returns: (y (B,S,H,P), final_state (B,H,P,N))
    """
    b, s, h, p = x.shape
    g, n = b_mat.shape[-2], b_mat.shape[-1]
    q = chunk
    nc = s // q
    hg = h // g  # heads per group

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = b_mat.reshape(b, nc, q, g, n)
    cc = c_mat.reshape(b, nc, q, g, n)

    # decay within chunk: a_bar (B, H, NC, Q)
    a_bar = (dtc * a_coef[None, None, None, :]).transpose(0, 3, 1, 2)
    a_cum = jnp.cumsum(a_bar, axis=-1)

    # intra-chunk (attention-like, the act x act-shaped matmuls):
    l_mat = jnp.exp(_segsum(a_bar))  # (B,H,NC,Q,Q)
    cb = jnp.einsum("bclgn,bcsgn->bcgls", cc, bc)  # (B,NC,G,Q,Q)
    cb = jnp.repeat(cb, hg, axis=2)  # -> (B,NC,H,Q,Q)
    lh = l_mat.transpose(0, 2, 1, 3, 4)  # (B,NC,H,Q,Q)
    dt_x = xc * dtc[..., None]  # (B,NC,Q,H,P)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", cb * lh, dt_x)

    # chunk states: (B,NC,H,P,N).  (n_groups == 1: the g index reduces
    # trivially; grouped B/C with G > 1 would need a head->group gather.)
    assert g == 1, "chunked SSD implemented for n_groups == 1"
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,H,NC,Q)
    states = jnp.einsum("bcsgn,bhcs,bcshp->bchpn", bc, decay_states, dt_x)

    # inter-chunk recurrence over NC (sequential scan; NC is small)
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B,H,NC)

    def step(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state BEFORE this chunk

    init = (
        init_state
        if init_state is not None
        else jnp.zeros((b, h, p, n), x.dtype)
    )
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,P,N)

    # contribution of carried state: (B,NC,Q,H,P)
    state_decay = jnp.exp(a_cum)  # (B,H,NC,Q)
    c_h = jnp.repeat(cc, hg, axis=3)  # (B,NC,Q,H,N) group -> heads
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", c_h, prev_states, state_decay
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssd_mixer(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    mode: str,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    gz = s_cfg.n_groups * s_cfg.d_state
    b, s, _ = x.shape

    zxbcdt = L.qlinear(p["in_proj"], x, cfg.quant, mode, name="ssm.in_proj")
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * gz], axis=-1)
    # xbc: (B, S, di + 2*gz) goes through the short conv
    # conv window is STORED in the state-slot dtype (derived from the live
    # leaf, so prefill writes can never drift from init_ssd_state — the PR 6
    # bug class) but COMPUTED in the activation dtype, like the rglru path.
    conv_dtype = state["conv"].dtype if state is not None else jnp.float32
    if state is not None and s == 1:
        conv_in = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv = conv_in[:, 1:].astype(conv_dtype)
    else:
        pad = jnp.zeros((b, s_cfg.d_conv - 1, xbc.shape[-1]), xbc.dtype)
        conv_in = jnp.concatenate([pad, xbc], axis=1)
        new_conv = conv_in[:, -(s_cfg.d_conv - 1) :].astype(conv_dtype)
    # depthwise causal conv via windowed sum
    w = p["conv_w"].astype(conv_in.dtype)  # (d_conv, C)
    conv_out = sum(conv_in[:, i : i + s] * w[i] for i in range(s_cfg.d_conv))
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)

    xs, b_mat, c_mat = jnp.split(xbc, [di, di + gz], axis=-1)
    xh = xs.reshape(b, s, nh, s_cfg.head_dim)
    bm = b_mat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    cm = c_mat.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_coef = -jnp.exp(p["A_log"])  # (H,)

    if state is not None and s == 1:
        # O(1) decode step: h' = exp(dt*A) h + dt * B x ; y = C h' + D x
        st = state["ssm"]
        dec = jnp.exp(dt[:, 0] * a_coef[None, :])  # (B,H)
        bm0 = jnp.repeat(bm[:, 0], nh // s_cfg.n_groups, axis=1)  # (B,H,N)
        cm0 = jnp.repeat(cm[:, 0], nh // s_cfg.n_groups, axis=1)
        upd = (dt[:, 0, :, None] * xh[:, 0])[..., None] * bm0[:, :, None, :]
        new_st = st * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", new_st, cm0)
        y = y + p["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, di)
        new_state = {"ssm": new_st, "conv": new_conv, "pos": state["pos"] + 1}
    else:
        q = min(s_cfg.chunk, s)
        pad_len = (-s) % q
        if pad_len:
            padf = lambda a: jnp.pad(a, [(0, 0), (0, pad_len)] + [(0, 0)] * (a.ndim - 2))
            xh, bm, cm = padf(xh), padf(bm), padf(cm)
            dt = jnp.pad(dt, [(0, 0), (0, pad_len), (0, 0)])
        init_state = state["ssm"] if state is not None else None
        y, fin = _ssd_chunked(
            xh.astype(jnp.float32), dt, a_coef, bm.astype(jnp.float32),
            cm.astype(jnp.float32), q, init_state,
        )
        y = y[:, :s]
        y = y + p["D"][None, None, :, None] * xh[:, :s].astype(jnp.float32)
        y = y.reshape(b, s, di)
        new_state = None
        if state is not None:
            new_state = {"ssm": fin, "conv": new_conv, "pos": state["pos"] + s}

    # gated RMSNorm then output projection (both full-precision norm + QMM)
    y = L.rmsnorm(p["norm_g"], y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), cfg.norm_eps)
    out = L.qlinear(p["out_proj"], y, cfg.quant, mode, name="ssm.out_proj")
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma recurrent block)
# ---------------------------------------------------------------------------


def init_rglru(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di = d  # recurrence width = d_model
    ks = jax.random.split(key, 6)
    return {
        "in_x": L.init_linear(ks[0], d, di),
        "in_gate": L.init_linear(ks[1], d, di),
        "conv_w": jax.random.normal(ks[2], (4, di), jnp.float32) * 0.2,
        "gate_a": L.init_linear(ks[3], di, di),  # recurrence gate r_t
        "gate_i": L.init_linear(ks[4], di, di),  # input gate i_t
        "lambda_p": jnp.ones((di,), jnp.float32) * 4.0,  # a = sigmoid(lambda)
        "out": L.init_linear(ks[5], di, d, scale=0.5),
    }


def init_rglru_state(batch: int, cfg: ArchConfig) -> dict:
    di = cfg.d_model
    return {
        "h": jnp.zeros((batch, di), jnp.float32),
        "conv": jnp.zeros((batch, 3, di), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),  # per-row cursor (serving slots)
    }


_RGLRU_C = 8.0


def rglru_mixer(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    mode: str,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """RG-LRU block (Griffin/recurrentgemma):
    branches -> conv1d(4) -> gated linear recurrence -> gated output."""
    b, s, d = x.shape
    xb = L.qlinear(p["in_x"], x, cfg.quant, mode, name="rglru.in_x")
    gate = L.qlinear(p["in_gate"], x, cfg.quant, mode, name="rglru.in_gate")

    # causal depthwise conv width 4; the stored window keeps the state-slot
    # dtype (derived from the live leaf, never a literal)
    conv_dtype = state["conv"].dtype if state is not None else jnp.float32
    if state is not None and s == 1:
        conv_in = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)
        new_conv = conv_in[:, 1:].astype(conv_dtype)
    else:
        pad = jnp.zeros((b, 3, xb.shape[-1]), xb.dtype)
        conv_in = jnp.concatenate([pad, xb], axis=1)
        new_conv = conv_in[:, -3:].astype(conv_dtype)
    w = p["conv_w"].astype(conv_in.dtype)
    xb = sum(conv_in[:, i : i + s] * w[i] for i in range(4))

    # gates (full precision — elementwise, not QMMs)
    r = jax.nn.sigmoid(
        L.qlinear(p["gate_a"], xb, cfg.quant, mode, name="rglru.gate_a").astype(
            jnp.float32
        )
    )
    i_g = jax.nn.sigmoid(
        L.qlinear(p["gate_i"], xb, cfg.quant, mode, name="rglru.gate_i").astype(
            jnp.float32
        )
    )
    log_a_base = -_RGLRU_C * jax.nn.softplus(p["lambda_p"])  # log sigmoid-param
    log_a = log_a_base[None, None, :] * r  # (B,S,di)
    a = jnp.exp(log_a)
    gated_x = xb.astype(jnp.float32) * i_g
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))

    if state is not None and s == 1:
        h = a[:, 0] * state["h"] + mult[:, 0] * gated_x[:, 0]
        y = h[:, None]
        new_state = {"h": h, "conv": new_conv, "pos": state["pos"] + 1}
    else:
        # associative linear recurrence h_t = a_t h_{t-1} + b_t
        bt = mult * gated_x

        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, a2 * b1 + b2

        a_scan, y = jax.lax.associative_scan(combine, (a, bt), axis=1)
        if state is not None:
            h0 = state["h"]
            y = y + a_scan * h0[:, None, :]
            new_state = {"h": y[:, -1], "conv": new_conv, "pos": state["pos"] + s}
        else:
            new_state = None

    out = y.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return L.qlinear(p["out"], out, cfg.quant, mode, name="rglru.out"), new_state
