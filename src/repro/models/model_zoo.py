"""Public model API: build any assigned architecture from its ArchConfig.

Entry points (all pure functions of explicit params — pjit-ready):

* ``init_params(key, cfg)``            — training params (latent fp32)
* ``prepare_serving_params(params)``   — offline: binarize, bit-pack, colsums
* ``loss_fn(params, batch, cfg, mode)``— LM loss (+ MoE aux, + MTP)
* ``forward_logits(...)``              — full-sequence logits
* ``init_cache(batch, max_len, cfg)``  — serving caches (quantized KV)
* ``prefill(...)`` / ``decode_step(...)``

Frontends per the assignment: ``[audio]``/``[vlm]`` entries stub the
modality encoder — ``input_specs`` (launch/dryrun.py) provides precomputed
frame/patch embeddings; the transformer backbone is the real deliverable.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.models import layers as L
from repro.models import moe as M
from repro.models import transformer as T

__all__ = [
    "init_params",
    "prepare_serving_params",
    "loss_fn",
    "forward_logits",
    "init_cache",
    "init_slot_cache",
    "cache_insert",
    "cache_reset",
    "prefill",
    "decode_step",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {
        "embedding": jax.random.normal(ks[0], (cfg.vocab_size, d), jnp.float32)
        * 0.02,
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["unembedding"] = (
            jax.random.normal(ks[1], (cfg.vocab_size, d), jnp.float32) * 0.02
        )
    cross = cfg.encoder is not None and cfg.encoder.n_layers > 0
    p["stack"] = T.init_stack(ks[2], cfg, cross=cross)

    if cfg.encoder is not None:
        enc: dict = {}
        d_in = cfg.encoder.d_input or d
        enc["stub_proj"] = L.init_linear(ks[3], d_in, d)
        if cfg.encoder.n_layers:
            # a small bidirectional transformer on top of the stub (whisper)
            enc_cfg = _encoder_cfg(cfg)
            enc["stack"] = T.init_stack(ks[4], enc_cfg)
            enc["final_norm"] = jnp.zeros((d,), jnp.float32)
        p["encoder"] = enc

    if cfg.pos_embedding == "learned":
        p["pos_embedding"] = (
            jax.random.normal(ks[5], (cfg.max_seq, d), jnp.float32) * 0.02
        )

    if cfg.mtp_depth:
        p["mtp"] = {"proj": L.init_linear(ks[6], 2 * d, d)}
    return p


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-encoder",
        n_layers=cfg.encoder.n_layers,
        prefix_layers=(),
        pattern_period=("g",),
        causal=False,
        pos_embedding="sinusoidal",
        encoder=None,
        mtp_depth=0,
    )


# ---------------------------------------------------------------------------
# serving weight pipeline (offline, like the paper's folded coefficients)
# ---------------------------------------------------------------------------

_FP_LEAF_PATHS = ("router", "stub_proj")  # accuracy-critical, kept FP


def prepare_serving_params(params: dict, cfg: ArchConfig):
    """Binarize + bit-pack every QMM weight; keep FP leaves (norms, router,
    embeddings, frontend stubs, recurrence gains) as bf16/fp32.

    Inside the scanned ``period`` subtree every weight carries an extra
    leading scan dim — packing is vmapped over it, so serving params keep
    the exact pytree structure ``stack_apply`` consumes.
    """

    def pack_leaf(node, stacked: bool):
        w = node["w"]
        base_ndim = w.ndim - (1 if stacked else 0)
        if base_ndim == 2:
            fn = lambda n: L.pack_linear_for_serving(n, cfg.quant)
        elif base_ndim == 3:
            fn = lambda n: M.pack_experts_for_serving(n, cfg.quant)
        else:
            raise ValueError(f"unexpected weight rank {w.ndim} (stacked={stacked})")
        return jax.vmap(fn)(node) if stacked else fn(node)

    def walk(node, path, stacked):
        if isinstance(node, dict):
            if "w" in node and len(node) == 1:
                if any(s in path for s in _FP_LEAF_PATHS):
                    return {"w": node["w"].astype(jnp.float32)}
                return pack_leaf(node, stacked)
            return {k: walk(v, path + (k,), stacked or k == "period") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (str(i),), stacked) for i, v in enumerate(node))
        if hasattr(node, "dtype") and jnp.issubdtype(node.dtype, jnp.floating):
            if path and path[-1] in ("embedding", "unembedding", "pos_embedding"):
                return node.astype(jnp.bfloat16)
            return node
        return node

    return walk(params, (), False)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / (half - 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_inputs(params, tokens, cfg: ArchConfig, positions, frontend=None, mode="train"):
    x = L.embed(params, tokens, cfg.d_model)
    if cfg.pos_embedding == "learned":
        pe = jnp.take(params["pos_embedding"], positions, axis=0)
        x = x + pe.astype(x.dtype)
    if (
        cfg.encoder is not None
        and cfg.encoder.kind == "patch_stub"
        and frontend is not None
    ):
        # VLM: splice projected patch embeddings over the first positions.
        patches = L.qlinear(
            params["encoder"]["stub_proj"], frontend.astype(x.dtype), cfg.quant, "float"
        )
        n = patches.shape[1]
        x = jnp.concatenate([patches.astype(x.dtype), x[:, n:]], axis=1)
    return x


def _run_encoder(params, frontend, cfg: ArchConfig, mode: str):
    """Whisper-style encoder over stub frame embeddings. Returns (B, T, D)."""
    enc = params["encoder"]
    x = L.qlinear(enc["stub_proj"], frontend, cfg.quant, "float")
    t = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(t), x.shape[:2])
    x = x + _sinusoidal(pos, cfg.d_model).astype(x.dtype)
    enc_cfg = _encoder_cfg(cfg)
    x, _, _ = T.stack_apply(enc["stack"], x, enc_cfg, mode, pos)
    return L.rmsnorm(enc["final_norm"], x, cfg.norm_eps)


def _forward_hidden(
    params: dict,
    tokens: jax.Array,
    cfg: ArchConfig,
    mode: str = "train",
    frontend: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward to the final (normed) hidden states."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    encoder_out = None
    if cfg.encoder is not None and cfg.encoder.n_layers and frontend is not None:
        encoder_out = _run_encoder(params, frontend, cfg, mode)
    x = _embed_inputs(params, tokens, cfg, positions, frontend, mode)
    x = x.astype(jnp.bfloat16)
    x, _, aux = T.stack_apply(
        params["stack"], x, cfg, mode, positions, None, encoder_out
    )
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def forward_logits(
    params: dict,
    tokens: jax.Array,
    cfg: ArchConfig,
    mode: str = "train",
    frontend: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss)."""
    x, aux = _forward_hidden(params, tokens, cfg, mode, frontend)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    return logits, aux


def loss_fn(
    params: dict,
    batch: dict,
    cfg: ArchConfig,
    mode: str = "train",
    aux_weight: float = 0.01,
):
    """Next-token LM loss (+ MoE balance aux + MTP head for deepseek-v3).

    batch: {"tokens": (B,S) int32, optional "frontend": stub embeddings}.
    Encoder-only archs (BERT family) use the denoising-copy objective —
    systems-equivalent supervision (DESIGN.md).
    """
    tokens = batch["tokens"]
    hidden, aux = _forward_hidden(params, tokens, cfg, mode, batch.get("frontend"))
    ldt = jnp.bfloat16 if cfg.logits_dtype == "bf16" else jnp.float32
    logits = L.unembed(params, hidden, cfg.tie_embeddings, ldt)
    if cfg.causal:
        pred, tgt = logits[:, :-1], tokens[:, 1:]
    else:
        pred, tgt = logits, tokens
    logp = jax.nn.log_softmax(pred, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll.astype(jnp.float32))

    if cfg.mtp_depth and "mtp" in params and cfg.causal:
        # depth-1 MTP (deepseek-v3): predict t+2 from [h_t ; emb(t+1)],
        # sharing the unembedding (training-loss only; serving ignores it).
        h_t = hidden[:, :-2].astype(jnp.float32)
        emb_next = L.embed(params, tokens[:, 1:-1], cfg.d_model).astype(jnp.float32)
        mtp_in = jnp.concatenate([h_t, emb_next], axis=-1)
        h_mtp = L.qlinear(params["mtp"]["proj"], mtp_in, cfg.quant, mode)
        mtp_logits = L.unembed(params, h_mtp, cfg.tie_embeddings)
        mlogp = jax.nn.log_softmax(mtp_logits.astype(jnp.float32), axis=-1)
        mtp_nll = -jnp.take_along_axis(
            mlogp, tokens[:, 2:][..., None], axis=-1
        )[..., 0]
        loss = loss + 0.3 * jnp.mean(mtp_nll)

    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "nll": loss}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(batch: int, max_len: int, cfg: ArchConfig) -> dict:
    cache = {"stack": T.init_stack_cache(batch, max_len, cfg)}
    if cfg.encoder is not None and cfg.encoder.n_layers:
        cache["encoder_out"] = jnp.zeros(
            (batch, cfg.encoder.n_positions, cfg.d_model), jnp.bfloat16
        )
    return cache


def init_slot_cache(max_len: int, cfg: ArchConfig) -> dict:
    """A batch-1 cache suitable for ``cache_insert`` into a packed batch.

    Continuous-batching serving prefills each admitted request into one of
    these (exact prompt length, no padding) and then splices it into its
    decode slot — the slot cache MUST share ``max_len`` with the packed
    cache so every leaf lines up except the batch axis.
    """
    return init_cache(1, max_len, cfg)


def _insert_leaf(dst, src, slot, axis: int):
    return jax.lax.dynamic_update_slice_in_dim(dst, src.astype(dst.dtype), slot, axis)


def cache_insert(cache: dict, slot_cache: dict, slot) -> dict:
    """Splice a batch-1 ``slot_cache`` into row ``slot`` of a packed cache.

    Every per-row cache leaf carries the batch axis at 0 (prefix layers,
    encoder_out) or 1 (scanned ``period`` layers, whose leading axis is the
    scan dim) — including the per-row ``pos`` cursors and quantization
    affines, so the inserted request resumes at its own position with its
    own calibration while other slots keep decoding.  ``slot`` may be a
    Python int or a traced scalar (jit-safe).
    """
    stack, s_stack = cache["stack"], slot_cache["stack"]
    prefix = jax.tree.map(
        lambda d, s: _insert_leaf(d, s, slot, 0), stack["prefix"], s_stack["prefix"]
    )
    period = jax.tree.map(
        lambda d, s: _insert_leaf(d, s, slot, 1), stack["period"], s_stack["period"]
    )
    out = dict(cache, stack=dict(stack, prefix=prefix, period=period))
    if "encoder_out" in cache:
        out["encoder_out"] = _insert_leaf(
            cache["encoder_out"], slot_cache["encoder_out"], slot, 0
        )
    return out


def cache_reset(cache: dict, slot, cfg: ArchConfig, max_len: int) -> dict:
    """Zero row ``slot`` of a packed cache (freed when a request finishes):
    position cursor back to 0, calibration affines back to identity."""
    return cache_insert(cache, init_slot_cache(max_len, cfg), slot)


def prefill(
    params: dict,
    tokens: jax.Array,
    cfg: ArchConfig,
    cache: dict,
    frontend: Optional[jax.Array] = None,
    length: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Process the prompt; returns (last-position logits (B,V), cache).

    Runs under the "prefill" autotune phase: its QMMs see M = batch x
    prompt, orders of magnitude larger than decode's M = batch, so the
    measured backend choice is tuned (and cached) independently.

    ``length``: optional (B,) actual prompt lengths for RIGHT-padded
    batches (bucketed prefill).  Logits are taken at ``length - 1`` per
    row and cache cursors are rewound to ``length`` so decode overwrites
    the pad region.  Pads are causally invisible to real tokens, but this
    is exact only for float full-attention caches: quantized-KV
    calibration sees the pads, windowed rings evict real tokens once the
    padded length reaches the window, and SSM recurrences integrate pad
    steps.  Exact-length prefill (``length=None``, no padding) is the
    default and what the continuous-batching engine uses.
    """
    with dispatch.tuning_phase("prefill"):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        encoder_out = None
        if cfg.encoder is not None and cfg.encoder.n_layers and frontend is not None:
            encoder_out = _run_encoder(params, frontend, cfg, "serve")
            # cache-slot dtype derives from the init leaf (never a literal)
            cache = dict(
                cache, encoder_out=encoder_out.astype(cache["encoder_out"].dtype)
            )
        x = _embed_inputs(params, tokens, cfg, positions, frontend, "serve")
        x = x.astype(jnp.bfloat16)
        x, new_stack, _ = T.stack_apply(
            params["stack"], x, cfg, "serve", positions, cache["stack"], encoder_out
        )
        if length is None:
            x_last = x[:, -1:]
        else:
            rows = jnp.asarray(length, jnp.int32).reshape(-1)
            idx = jnp.broadcast_to((rows - 1)[:, None, None], (b, 1, x.shape[-1]))
            x_last = jnp.take_along_axis(x, idx, axis=1)
            new_stack = _set_stack_pos(new_stack, rows)
        x = L.rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
        logits = L.unembed(params, x, cfg.tie_embeddings)[:, 0]
        return logits, dict(cache, stack=new_stack)


def decode_step(
    params: dict,
    tokens: jax.Array,
    cfg: ArchConfig,
    cache: dict,
) -> Tuple[jax.Array, dict]:
    """One decode step. tokens (B,) int32 -> logits (B, V) + updated cache.

    Runs under the "decode" autotune phase (see ``prefill``)."""
    with dispatch.tuning_phase("decode"):
        b = tokens.shape[0]
        pos_rows = jnp.reshape(_cache_pos(cache["stack"], cfg), (-1,))
        positions = jnp.broadcast_to(pos_rows[:, None], (b, 1))
        x = L.embed(params, tokens[:, None], cfg.d_model)
        if cfg.pos_embedding == "learned":
            pe = jnp.take(params["pos_embedding"], positions, axis=0)
            x = x + pe.astype(x.dtype)
        x = x.astype(jnp.bfloat16)
        encoder_out = cache.get("encoder_out")
        x, new_stack, _ = T.stack_apply(
            params["stack"], x, cfg, "serve", positions, cache["stack"], encoder_out
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params, x, cfg.tie_embeddings)[:, 0]
        return logits, dict(cache, stack=new_stack)


def _cache_pos(stack_cache: dict, cfg: ArchConfig):
    """Per-row (B,) position cursors of the first layer's cache."""
    if stack_cache["prefix"]:
        return stack_cache["prefix"][0]["pos"]
    return stack_cache["period"][0]["pos"][0]


def _set_stack_pos(stack_cache: dict, rows: jax.Array) -> dict:
    """Overwrite every layer's ``pos`` cursor with per-row values (B,)."""

    def fix(c):
        if isinstance(c, dict) and "pos" in c:
            pos = jnp.broadcast_to(rows, c["pos"].shape).astype(c["pos"].dtype)
            return dict(c, pos=pos)
        return c

    return dict(
        stack_cache,
        prefix=[fix(c) for c in stack_cache["prefix"]],
        period=[fix(c) for c in stack_cache["period"]],
    )
