"""Quantization-aware building blocks shared by every architecture.

Three execution modes thread through all layers (``mode``):

* ``"train"`` — QAT: latent fp32 weights fake-binarized with STE, activations
  fake-quantized; matmuls stay float so gradients flow.  This is how the
  paper's benchmark models (BiT et al.) are produced.
* ``"serve"`` — the BETA datapath: weights live bit-packed (uint32) with
  per-channel scale/offset + precomputed colsum; activations are quantized to
  the engine's mode and the product runs through the flow abstraction on an
  integer core.  What the accelerator executes.
* ``"float"`` — full-precision baseline (the paper's FP-32/FIX-16 rows).

Params are plain nested dicts of jnp arrays (pjit-friendly); serving params
are produced from train params by ``prepare_serving_params`` (model_zoo).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, QuantConfig
from repro.core import flow_abstraction as FA
from repro.core import packing
from repro.core import qmm as QE
from repro.core import quantization as Q
from repro.core import site_log

__all__ = [
    "qlinear",
    "init_linear",
    "pack_linear_for_serving",
    "rmsnorm",
    "layernorm",
    "rope",
    "ffn",
    "init_ffn",
    "embed",
    "unembed",
]

# ---------------------------------------------------------------------------
# quant-aware linear
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / (d_in**0.5)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}


def pack_linear_for_serving(p: dict, quant: QuantConfig) -> dict:
    """Offline weight pipeline (the paper's 'performed offline' step):
    binarize -> bit-pack along K -> precompute colsum corrections."""
    if not quant.enabled:
        return {"w": p["w"].astype(jnp.bfloat16)}
    wq = Q.quantize_weight(p["w"], quant.weight_bits, per_channel_axis=-1)
    colsum = FA.weight_corrections(wq)
    packed = wq.pack(axis=0)
    return {
        "w_packed": packed.mantissa,  # uint32 (K/32, N)
        "w_scale": packed.scale.astype(jnp.float32),  # (1, N)
        "w_offset": packed.offset.astype(jnp.float32),
        "w_colsum": colsum.astype(jnp.int32),  # (N,)
    }


def _serving_weight(p: dict, k: int, quant: QuantConfig) -> Q.QuantTensor:
    return Q.QuantTensor(
        mantissa=p["w_packed"],
        scale=p["w_scale"],
        offset=p["w_offset"],
        bits=quant.weight_bits,
        packed=True,
        packed_axis=0,
        length=k,
    )


def qlinear(
    p: dict,
    x: jax.Array,
    quant: QuantConfig,
    mode: str,
    *,
    act_bits: Optional[int] = None,
    name: str = "",
) -> jax.Array:
    """``x (..., K) @ W (K, N)`` in the configured execution mode.

    ``name`` identifies the layer site (e.g. "ffn.up") for per-layer backend
    overrides (``QuantConfig.backend_overrides``); unnamed sites use the
    config's default backend.
    """
    if mode == "float" or not quant.enabled:
        w = p["w"] if "w" in p else None
        if w is None:
            raise ValueError("float mode needs latent weights")
        return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))

    bits = act_bits or quant.act_bits

    if mode == "train":
        if quant.prebinarize_gather:
            # weights arrive pre-binarized (packed-gather STE upstream)
            w_hat = p["w"]
        else:
            w_hat = Q.fake_binarize_weight(p["w"], per_channel_axis=-1)
        x_hat = Q.fake_quant(x, bits)
        return jnp.einsum("...k,kn->...n", x_hat, w_hat.astype(x.dtype))

    if mode == "serve":
        k = x.shape[-1]
        wq = _serving_weight(p, k, quant)
        lead = x.shape[:-1]
        # per-token calibration on the flattened (M, K) view: each row gets
        # its own grid, so co-batched serving slots stay numerically
        # independent (batch invariance) — the epilogue broadcasts (M, 1)
        xq = Q.quantize_activation(
            x.astype(jnp.float32).reshape(-1, k), bits, per_channel_axis=0
        )
        x2 = Q.QuantTensor(
            mantissa=xq.mantissa,
            scale=xq.scale,
            offset=xq.offset,
            bits=bits,
        )
        if site_log.is_recording():
            site_log.record(
                kind="qlinear",
                site=name,
                bits=bits,
                cfg_bits=quant.act_bits,
                mantissa_dtype=str(xq.mantissa.dtype),
                backend=quant.backend_for(name),
            )
        out = QE.qmm(
            x2, wq, backend=quant.backend_for(name), w_colsum=p.get("w_colsum")
        )
        return out.reshape(*lead, -1).astype(x.dtype)

    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# norms / positions / activations
# ---------------------------------------------------------------------------


def rmsnorm(g: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + g.astype(jnp.float32))).astype(
        x.dtype
    )


def layernorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


def rope(
    x: jax.Array, positions: jax.Array, theta: float, dtype=jnp.float32
) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    if x.ndim == angles.ndim + 1:  # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _act(name: str, x: jax.Array) -> jax.Array:
    if name.startswith("gelu"):
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_ffn(key, cfg_ffn_type: str, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": init_linear(k1, d_model, d_ff),
        "down": init_linear(k2, d_ff, d_model, scale=0.5),
    }
    if cfg_ffn_type.endswith("glu"):
        p["gate"] = init_linear(k3, d_model, d_ff)
    return p


def ffn(
    p: dict,
    x: jax.Array,
    ffn_type: str,
    quant: QuantConfig,
    mode: str,
    name: str = "ffn",
):
    up = qlinear(p["up"], x, quant, mode, name=f"{name}.up")
    if ffn_type.endswith("glu"):
        gate = qlinear(p["gate"], x, quant, mode, name=f"{name}.gate")
        h = _act(ffn_type, gate) * up
    else:
        h = _act(ffn_type, up)
    return qlinear(p["down"], h, quant, mode, name=f"{name}.down")


# ---------------------------------------------------------------------------
# embeddings (kept full-precision, as the paper's benchmark models do)
# ---------------------------------------------------------------------------


def embed(p: dict, tokens: jax.Array, d_model: int, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(dtype) * jnp.asarray(
        d_model**0.5, dtype
    )


def unembed(p: dict, x: jax.Array, tied: bool, dtype=jnp.float32) -> jax.Array:
    table = p["embedding"] if tied else p["unembedding"]
    return jnp.einsum("...d,vd->...v", x.astype(dtype), table.astype(dtype))
