"""Attention mixers: GQA (global/local), MLA, cross-attention — quant-aware.

BETA-specific parts:

* In ``serve`` mode the two attention matmuls (QK^T and PV) run as
  **activation x activation QMMs** through the flow abstraction — the QMM
  type the paper highlights as unsupported by prior accelerators (§II).
  Softmax stays full-precision (paper keeps non-linear ops FP).
* The KV cache is stored **quantized** (int8 mantissa + affine), so the
  decode-time memory roofline term shrinks ~2x vs bf16 (and the cache *is*
  the right operand of the act x act QMM — no dequantization pass).
* Scales: Q/K per-tensor; K-cache per-token scales would also factor through
  the flow abstraction (per-column of K^T), but per-tensor is within test
  tolerance and keeps the epilogue rank-1; V per-tensor (per-reduction-dim
  scales do not factor out of an integer MM — DESIGN.md §7).

Layouts: activations ``(B, S, D)``; q ``(B, S, H, dh)``; caches
``(B, T, kvH, dh)``; decode processes ``S = 1`` with positions from the
cache cursor.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, QuantConfig
from repro.core import backend_registry, packing
from repro.core import flow_abstraction as FA
from repro.core import quantization as Q
from repro.core import site_log
from repro.kernels import ops as K_ops
from repro.models import layers as L

__all__ = [
    "init_attention",
    "attention",
    "init_kv_cache",
    "init_mla",
    "mla_attention",
    "init_mla_cache",
]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, cross: bool = False) -> dict:
    h, kvh, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "q": L.init_linear(ks[0], d, h * dh),
        "k": L.init_linear(ks[1], d, kvh * dh),
        "v": L.init_linear(ks[2], d, kvh * dh),
        "o": L.init_linear(ks[3], h * dh, d, scale=0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# KV cache (quantized)
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, max_len: int, cfg: ArchConfig, kind: str = "g", dtype=jnp.bfloat16
) -> dict:
    """KV cache with PER-ROW serving state.

    ``pos`` and the calibration affines are shape ``(batch,)``: each batch
    row (a serving *slot*) carries its own cursor and quantization grid, so
    a packed decode batch may hold requests at different sequence positions
    (continuous batching) and a slot prefilled alone is bit-identical to the
    same request served in a full batch.
    """
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    q = cfg.quant
    if kind == "l" and cfg.window_size:
        # ring buffer: local layers never need more than window_size slots
        max_len = min(max_len, cfg.window_size)
    if q.enabled and q.kv_cache_bits in (4, 8):
        if _binary_scores_site(q, "attn.qk") is not None:
            # Bitwise attention engaged: K rows are stored as PACKED 1-bit
            # planes (uint32, dh bits little-endian along the last axis) —
            # the ~8-16x KV memory shrink vs int8/bf16.  V stays int8 (the
            # PV act x act QMM is unchanged).
            dw = packing.packed_len(dh, 1)
            return {
                "k": jnp.zeros((batch, max_len, kvh, dw), jnp.uint32),
                "v": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
                "k_scale": jnp.ones((batch,), jnp.float32),
                "k_offset": jnp.zeros((batch,), jnp.float32),
                "v_scale": jnp.ones((batch,), jnp.float32),
                "v_offset": jnp.zeros((batch,), jnp.float32),
                "pos": jnp.zeros((batch,), jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
            "v": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
            "k_scale": jnp.ones((batch,), jnp.float32),
            "k_offset": jnp.zeros((batch,), jnp.float32),
            "v_scale": jnp.ones((batch,), jnp.float32),
            "v_offset": jnp.zeros((batch,), jnp.float32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, kvh, dh), dtype),
        "v": jnp.zeros((batch, max_len, kvh, dh), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _cache_quantized(cache: dict) -> bool:
    return cache is not None and "k_scale" in cache


def _per_row(s, ndim: int):
    """Broadcast a per-row ``(B,)`` cache affine against a ``(B, ...)``
    operand of rank ``ndim`` (legacy scalar values pass through)."""
    s = jnp.asarray(s)
    if s.ndim == 0:
        return s
    return s.reshape(s.shape + (1,) * (ndim - 1))


def _calibrate_rows(x: jax.Array):
    """Per-row affine calibration: min/offset and (max-min)/255 scale reduced
    over every axis but the batch row — co-batched requests never share a
    quantization grid (the batch-invariance contract)."""
    x32 = x.astype(jnp.float32).reshape(x.shape[0], -1)
    off = jnp.min(x32, axis=-1)
    sc = jnp.maximum((jnp.max(x32, axis=-1) - off) / 255.0, 1e-8)
    return sc, off


def _quantize_to_cache(x: jax.Array, scale, offset) -> jax.Array:
    """Quantize with a FIXED affine (prefill-calibrated), re-centered int8."""
    scale = _per_row(scale, x.ndim)
    offset = _per_row(offset, x.ndim)
    q = jnp.clip(jnp.round((x.astype(jnp.float32) - offset) / scale), 0.0, 255.0)
    return (q - 128.0).astype(jnp.int8)


def _dequantize_from_cache(m: jax.Array, scale, offset, dtype):
    scale = _per_row(scale, m.ndim)
    offset = _per_row(offset, m.ndim)
    return ((m.astype(jnp.float32) + 128.0) * scale + offset).astype(dtype)


# ---------------------------------------------------------------------------
# bitwise attention (Bitformer scores via the scores backend family)
# ---------------------------------------------------------------------------


def _binary_scores_site(quant: QuantConfig, site: str) -> Optional[str]:
    """The scores-only backend configured for ``site``, or None.

    A scores-only name ("binary", "float") engages the bitwise attention
    path at that site; "auto" and qmm-family names leave the int8 path
    untouched — binarizing K is a precision choice, so it is strictly
    opt-in via ``backend_overrides={"attn.qk": "binary"}``.
    """
    if not (quant.enabled and quant.quantize_attention):
        return None
    name = quant.backend_for(site)
    if name == "auto":
        return None
    try:
        spec = backend_registry.get_backend(name)
    except ValueError:
        return None
    if "scores" in spec.families and "qmm" not in spec.families:
        return name
    return None


def _scores_core(site_backend: str) -> str:
    """Map a site override to the integer-core backend name.

    "binary" is the family engagement: its core is autotuned ("auto" over
    the scores candidates — binary vs mxu-int vs float, all bit-exact, so
    the verdict is pure speed).  Any other scores-only name pins its own
    core — "float" is the differential oracle's deterministic compute path.
    """
    return "auto" if site_backend == "binary" else site_backend


def _cache_binary(cache: Optional[dict]) -> bool:
    """Does this cache hold packed binary K planes (uint32 rows)?"""
    return cache is not None and "k" in cache and cache["k"].dtype == jnp.uint32


def _binarize_rows(x: jax.Array) -> Q.QuantTensor:
    """Per-row elastic binarization (BiT): the engine's 1-bit activation
    grid, min/max reduced over every axis but the batch row — co-batched
    requests never share a binarization grid (batch invariance)."""
    return Q.quantize_activation(x.astype(jnp.float32), 1, per_channel_axis=0)


def _binarize_to_cache(k: jax.Array, scale, offset) -> jax.Array:
    """Binarize with a FIXED affine (prefill-calibrated) and pack: the
    decode-time analogue of ``_quantize_to_cache`` for packed binary rows."""
    scale = _per_row(scale, k.ndim)
    offset = _per_row(offset, k.ndim)
    bit = jnp.clip(jnp.round((k.astype(jnp.float32) - offset) / scale), 0.0, 1.0)
    return packing.pack_bits(bit.astype(jnp.uint32), 1, axis=-1)


def _pack_q_heads(bits: jax.Array) -> jax.Array:
    """(B, S, H, dh) {0,1} mantissas -> (B, H, S, dw) packed uint32 planes
    (the scores-core operand layout)."""
    planes = packing.pack_bits(bits.astype(jnp.uint32), 1, axis=-1)
    return planes.transpose(0, 2, 1, 3)


def _plane_popcounts(planes: jax.Array) -> jax.Array:
    """Per-row bit totals straight off packed planes — exact (tail bits are
    zero by packing) and cheaper than unpacking just to sum."""
    return jnp.sum(
        jax.lax.population_count(planes).astype(jnp.int32), axis=-1
    ).astype(jnp.float32)


def _scores_binary(q, k_planes_t, k_scale, k_offset, dh: int, site: str, backend: str):
    """Bitwise QK^T: elastic 1-bit Q against packed binary K planes.

    AND-popcount counts from the dispatched scores core, then the affine
    epilogue back to the real-valued score domain (the algebra is in
    ``kernels.binary_attn``):

        scores = aq*ak*counts + aq*gk*rowsum(qb) + gq*ak*colsum(kb) + gq*gk*dh

    q: (B,S,H,dh) float.  k_planes_t: (B,kvH,T,dw) packed key bits.
    k_scale/k_offset: (B,) binarization affine of the cached keys (qmax=1
    grid — NO re-centering shift, unlike the int8 cache epilogue).
    """
    b, s, h, _ = q.shape
    g = h // k_planes_t.shape[1]
    qq = _binarize_rows(q)
    if site_log.is_recording():
        site_log.record(
            kind="attn",
            site=site,
            bits=1,
            mantissa_dtype=str(qq.mantissa.dtype),
            backend=backend,
        )
    q_planes = _pack_q_heads(qq.mantissa)  # (B,H,S,dw)
    counts = K_ops.binary_attn_scores(
        q_planes, k_planes_t, dh=dh, backend=_scores_core(backend)
    ).astype(jnp.float32)
    row = _plane_popcounts(q_planes)[..., None]  # (B,H,S,1)
    col = jnp.repeat(_plane_popcounts(k_planes_t), g, axis=1)[:, :, None, :]
    a1 = jnp.reshape(qq.scale, (b, 1, 1, 1))
    g1 = jnp.reshape(qq.offset, (b, 1, 1, 1))
    a2 = _per_row(k_scale, 4)
    g2 = _per_row(k_offset, 4)
    return counts * (a1 * a2) + (a1 * g2) * row + (g1 * a2) * col + g1 * g2 * dh


def _scores_binary_latent(q_abs, ckv_m, ckv_scale, ckv_offset, site: str, backend: str):
    """Bitwise absorbed-MLA scores against the int8 latent cache.

    The latent cache layout is UNCHANGED (int8 also feeds the PV QMM), so
    the key side re-binarizes each int8 mantissa at its grid midpoint:
    ``bit = (m >= 0)`` — per-element and deterministic, hence stale-free
    and batch-invariant — with the induced affine ``ak = 128*sc``,
    ``gk = off + 64*sc``.  The packed-cache memory win is GQA-only; this
    path buys the bitwise O(n^2) score core.  Returns (B,H,S,T).
    """
    b, s, h, r = q_abs.shape
    qq = _binarize_rows(q_abs)
    if site_log.is_recording():
        site_log.record(
            kind="attn",
            site=site,
            bits=1,
            mantissa_dtype=str(qq.mantissa.dtype),
            backend=backend,
        )
    q_planes = _pack_q_heads(qq.mantissa)  # (B,H,S,rw)
    k_bits = (ckv_m >= 0).astype(jnp.uint32)
    k_planes = packing.pack_bits(k_bits, 1, axis=-1)[:, None]  # (B,1,T,rw)
    counts = K_ops.binary_attn_scores(
        q_planes, k_planes, dh=r, backend=_scores_core(backend)
    ).astype(jnp.float32)
    row = _plane_popcounts(q_planes)[..., None]  # (B,H,S,1)
    col = _plane_popcounts(k_planes)[:, :, None, :]  # (B,1,1,T)
    sc = jnp.asarray(ckv_scale, jnp.float32)
    off = jnp.asarray(ckv_offset, jnp.float32)
    a1 = jnp.reshape(qq.scale, (b, 1, 1, 1))
    g1 = jnp.reshape(qq.offset, (b, 1, 1, 1))
    a2 = _per_row(128.0 * sc, 4)
    g2 = _per_row(off + 64.0 * sc, 4)
    return counts * (a1 * a2) + (a1 * g2) * row + (g1 * a2) * col + g1 * g2 * r


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _merge_heads(x):
    return x.reshape(*x.shape[:-2], -1)


def _gqa_expand(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, kvH, dh) -> (B, T, H, dh) by repeating groups.

    Kept only for reference/tests — the attention paths use the GROUPED
    einsums below, which never materialize (or all-gather) the expanded
    KV: repeating a model-sharded head axis forced XLA to gather the whole
    cache every step (the §Perf gemma3-decode baseline pathology)."""
    b, t, kvh, dh = k.shape
    if kvh == n_heads:
        return k
    rep = n_heads // kvh
    return jnp.repeat(k, rep, axis=2)


def _mask(
    s_q: int,
    s_k: int,
    q_start,
    causal: bool,
    window: int,
) -> jax.Array:
    """(s_q, s_k) additive mask. q_start: absolute position of query row 0."""
    qi = q_start + jnp.arange(s_q)[:, None]
    kj = jnp.arange(s_k)[None, :]
    ok = jnp.ones((s_q, s_k), bool)
    if causal:
        ok &= kj <= qi
    if window:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def _scores_float(q, k, dtype=jnp.float32):
    """Grouped GQA scores: q (B,S,H,dh) x k (B,T,kvH,dh) -> (B,H,S,T).

    q heads are reshaped (kvH, group) so the contraction runs against the
    UN-expanded k — kv heads stay sharded, no repeat, no gather.  Head
    ordering matches jnp.repeat semantics (head h -> kv h // group)."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    out = jnp.einsum("bskgd,btkd->bkgst", qg.astype(dtype), k.astype(dtype))
    return out.reshape(b, h, s, k.shape[1])


def _pv_float(probs, v, out_dtype):
    """Grouped GQA context: probs (B,H,S,T) x v (B,T,kvH,dh) -> (B,S,H,dh)."""
    b, h, s, t = probs.shape
    kvh = v.shape[2]
    g = h // kvh
    pg = probs.reshape(b, kvh, g, s, t)
    ctx = jnp.einsum("bkgst,btkd->bskgd", pg.astype(out_dtype), v.astype(out_dtype))
    return ctx.reshape(b, s, h, v.shape[3])


def _int_einsum(spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """int8 x int8 einsum with int32 accumulation.

    Keeps ALL batch dims explicit — merging a data-sharded batch dim with a
    model-sharded head dim (the reshape+batched-matmul formulation) forced
    the partitioner to all-gather whole KV caches per decode step
    (§Perf gemma3 baseline).  int32 safety: callers' contraction dims are
    dh (<=256) or a window/cache axis <= 128k; 128*128*131072 < 2^31.
    """
    return jnp.einsum(spec, a, b, preferred_element_type=jnp.int32)


def _scores_int(q, k_mantissa, k_scale, k_offset, attn_bits: int, backend: str = "auto"):
    """Integer QK^T via the flow abstraction (act x act QMM, paper type 2),
    GROUPED over kv heads (k stays un-expanded and kv-sharded; no dim
    merging — see _int_einsum).

    q: (B,S,H,dh) float -> quantized per-tensor.
    k_mantissa: (B,T,kvH,dh) int8 re-centered cache mantissas.
    ``backend`` is the site's configured name (site_log bookkeeping only —
    scores-only names never reach this function; see _binary_scores_site).
    """
    b, s, h, dh = q.shape
    t, kvh = k_mantissa.shape[1], k_mantissa.shape[2]
    g = h // kvh
    # per-row calibration (axis 0 kept): co-batched slots stay independent
    qq = Q.quantize_activation(q.astype(jnp.float32), attn_bits, per_channel_axis=0)
    qr = Q.recenter(qq)
    if site_log.is_recording():
        site_log.record(
            kind="attn",
            site="attn.qk",
            bits=attn_bits,
            mantissa_dtype=str(qr.mantissa.dtype),
            backend=backend,
        )
    x1 = qr.mantissa.reshape(b, s, kvh, g, dh)  # int8
    x2 = k_mantissa.astype(jnp.int8)  # (B,T,kvH,dh)
    xy = _int_einsum("bskgd,btkd->bkgst", x1, x2).astype(jnp.float32)
    # affine epilogue: q = a1*x1 + g1 ; k = a2*x2 + g2 (cache affine, recentered)
    a1 = jnp.reshape(qr.scale, (b, 1, 1, 1, 1))
    g1 = jnp.reshape(qr.offset, (b, 1, 1, 1, 1))
    a2 = _per_row(k_scale, 5)
    g2 = _per_row(k_offset, 5) + 128.0 * a2  # cache mantissa was re-centered by 128
    row = jnp.sum(x1, axis=-1, dtype=jnp.int32).astype(jnp.float32)  # (B,S,kvH,G)
    row = row.transpose(0, 2, 3, 1)[..., None]  # (B,kvH,G,S,1)
    col = jnp.sum(x2, axis=-1, dtype=jnp.int32).astype(jnp.float32)  # (B,T,kvH)
    col = col.transpose(0, 2, 1)[:, :, None, None, :]  # (B,kvH,1,1,T)
    out = xy * (a1 * a2) + (a1 * g2) * row + (g1 * a2) * col + g1 * g2 * dh
    return out.reshape(b, h, s, t)


def _write_prefill_cache(
    cache, k_m, v_m, s, cache_len, windowed, k_sc, k_off, v_sc, v_off
):
    """Write prefilled k/v (already in cache representation) into the cache.

    Full cache: place at [pos, pos+s).  Ring (windowed): keep only the last
    ``cache_len`` tokens, rolled so entry at absolute position p lands in
    slot ``p % W`` (assumes prefill starts from an empty cache — serving
    resets slots between requests).

    ``pos`` is per-row ``(B,)``; prefill requires all rows at the same
    cursor (in serving, prefill always runs on a freshly reset cache), so
    row 0's cursor indexes the batched write."""
    pos = jnp.reshape(cache["pos"], (-1,))[0]
    if windowed and s >= cache_len:
        keep_k = k_m[:, s - cache_len :]
        keep_v = v_m[:, s - cache_len :]
        shift = (s - cache_len) % cache_len
        new_k = jnp.roll(keep_k, shift, axis=1)
        new_v = jnp.roll(keep_v, shift, axis=1)
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_m, pos, 1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_m, pos, 1)
    out = dict(cache, k=new_k, v=new_v, pos=cache["pos"] + s)
    if k_sc is not None:
        out.update(k_scale=k_sc, k_offset=k_off, v_scale=v_sc, v_offset=v_off)
    return out


def _scores_int_latent(
    q_abs, ckv_m, ckv_scale, ckv_offset, attn_bits: int, backend: str = "auto"
):
    """Absorbed-MLA scores as one act x act QMM against the shared latent
    cache: ``scores[b,h,s,t] = sum_r q_abs[b,s,h,r] * ckv[b,t,r]``.

    The latent is head-shared, so heads fold into the M dim of a single
    integer MM per batch element (no H-fold copies of the int8 cache).
    """
    b, s, h, r = q_abs.shape
    t = ckv_m.shape[1]
    # per-row (per-slot) activation grid: co-scheduled requests must not
    # couple through a shared calibration (batch invariance)
    qq = Q.quantize_activation(q_abs.astype(jnp.float32), attn_bits, per_channel_axis=0)
    qr = Q.recenter(qq)
    if site_log.is_recording():
        site_log.record(
            kind="attn",
            site="attn.qk_latent",
            bits=attn_bits,
            mantissa_dtype=str(qr.mantissa.dtype),
            backend=backend,
        )
    x1 = qr.mantissa.reshape(b, s * h, r)
    x2 = jnp.swapaxes(ckv_m, -1, -2).astype(jnp.int8)  # (b, r, t)
    xy = FA.default_int_matmul(x1, x2, attn_bits, 8).astype(jnp.float32)
    a1 = jnp.reshape(qr.scale, (b, 1, 1))
    g1 = jnp.reshape(qr.offset, (b, 1, 1))
    a2 = _per_row(ckv_scale, 3)
    g2 = _per_row(ckv_offset, 3) + 128.0 * a2
    row = jnp.sum(x1, axis=-1, dtype=jnp.int32)[..., None].astype(jnp.float32)
    col = jnp.sum(x2, axis=-2, dtype=jnp.int32)[..., None, :].astype(jnp.float32)
    out = xy * (a1 * a2) + (a1 * g2) * row + (g1 * a2) * col + g1 * g2 * r
    return out.reshape(b, s, h, t).transpose(0, 2, 1, 3)


def _pv_int(p_probs, v_mantissa, v_scale, v_offset):
    """Integer P @ V via the flow abstraction, GROUPED over kv heads (no
    dim merging — see _int_einsum).

    p_probs: (B,H,S,T) softmax output in [0,1] — quantized exactly with
    scale 1/255, offset 0 (the engine's W8 activation grid).
    v_mantissa: (B,T,kvH,dh) int8 re-centered (un-expanded).
    """
    b, h, s, t = p_probs.shape
    kvh, dh = v_mantissa.shape[2], v_mantissa.shape[3]
    g = h // kvh
    pm = jnp.clip(jnp.round(p_probs * 255.0), 0, 255.0)
    x1 = (pm - 128.0).astype(jnp.int8).reshape(b, kvh, g, s, t)
    a1, g1 = jnp.float32(1.0 / 255.0), jnp.float32(128.0 / 255.0)
    x2 = v_mantissa.astype(jnp.int8)  # (B,T,kvH,dh)
    a2 = _per_row(v_scale, 5)
    g2 = _per_row(v_offset, 5) + 128.0 * a2
    xy = _int_einsum("bkgst,btkd->bkgsd", x1, x2).astype(jnp.float32)
    row = jnp.sum(x1, axis=-1, dtype=jnp.int32)[..., None].astype(jnp.float32)
    col = jnp.sum(x2, axis=1, dtype=jnp.int32).astype(jnp.float32)  # (B,kvH,dh)
    col = col[:, :, None, None, :]  # (B,kvH,1,1,dh)
    out = xy * (a1 * a2) + (a1 * g2) * row + (g1 * a2) * col + g1 * g2 * t
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)


# ---------------------------------------------------------------------------
# the mixer
# ---------------------------------------------------------------------------


def attention(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    kind: str,
    mode: str,
    positions: jax.Array,
    cache: Optional[dict] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: Optional[bool] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """One attention mixer application.

    Args:
      p: params from init_attention.
      x: (B, S, D) activations.
      cfg: arch config; ``kind`` "g" (global) or "l" (window cfg.window_size).
      mode: "train" | "serve" | "float".
      positions: (B, S) absolute positions of x.
      cache: KV cache dict (serving). None -> stateless full-seq attention.
      kv_override: (k, v) from an encoder (cross-attention); bypasses cache
        update and uses these as the full key/value set.
      causal: override cfg.causal (e.g. encoder self-attn inside a decoder
        stack).

    Returns:
      (out (B, S, D), updated cache or None)
    """
    quant = cfg.quant
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    b, s, _ = x.shape
    causal = cfg.causal if causal is None else causal
    window = cfg.window_size if kind == "l" else 0

    q = _split_heads(L.qlinear(p["q"], x, quant, mode, name="attn.q"), h, dh)
    if kv_override is None:
        k = _split_heads(L.qlinear(p["k"], x, quant, mode, name="attn.k"), kvh, dh)
        v = _split_heads(L.qlinear(p["v"], x, quant, mode, name="attn.v"), kvh, dh)
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        if kv_override is None:
            k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if cfg.pos_embedding == "rope" and kv_override is None:
        theta = (
            cfg.local_rope_theta
            if (kind == "l" and cfg.local_rope_theta)
            else cfg.rope_theta
        )
        q = L.rope(q, positions, theta)
        k = L.rope(k, positions, theta)

    # Cache geometry: local ("l") layers get a RING BUFFER of window_size
    # slots (init_kv_cache) — decode writes at ``pos % W`` and the slot's
    # absolute position is reconstructed for masking.  This bounds the
    # long-context memory term for local layers (the long_500k cells).
    cache_len = cache["k"].shape[1] if cache is not None else 0
    windowed = (
        cache is not None and kind == "l" and 0 < cfg.window_size == cache_len
    )
    quantized = _cache_quantized(cache)
    use_int = (
        mode == "serve"
        and quant.enabled
        and quant.quantize_attention
        and kv_override is None
        and (cache is None or quantized)
    )
    # Bitwise attention: a scores-only backend override on "attn.qk"
    # rebinarizes Q per call and stores K as packed 1-bit planes; the score
    # core dispatches through the scores backend family.
    qk_backend = quant.backend_for("attn.qk")
    use_binary = (
        use_int
        and _binary_scores_site(quant, "attn.qk") is not None
        and (cache is None or _cache_binary(cache))
    )
    new_cache = cache

    if s > 1 or cache is None:
        # ---- full-sequence attention over the in-flight k/v -------------
        # (training, or serving prefill from an empty cache)
        sdt = jnp.bfloat16 if cfg.attn_scores_dtype == "bf16" else jnp.float32
        expand = cfg.gqa_mode == "expand"
        if use_int and use_binary:
            kq = _binarize_rows(k)
            # cache affines are per-row (B,) — drop the keepdims axes
            k_sc = jnp.reshape(kq.scale, (b,))
            k_off = jnp.reshape(kq.offset, (b,))
            k_m = packing.pack_bits(kq.mantissa.astype(jnp.uint32), 1, axis=-1)
            v_sc, v_off = _calibrate_rows(v)
            v_m = _quantize_to_cache(v, v_sc, v_off)
            scores = _scores_binary(
                q, k_m.transpose(0, 2, 1, 3), k_sc, k_off, dh, "attn.qk", qk_backend
            )
        elif use_int:
            k_sc, k_off = _calibrate_rows(k)
            v_sc, v_off = _calibrate_rows(v)
            k_m = _quantize_to_cache(k, k_sc, k_off)
            v_m = _quantize_to_cache(v, v_sc, v_off)
            k_s = _gqa_expand(k_m, h) if expand else k_m
            scores = _scores_int(q, k_s, k_sc, k_off, quant.attn_act_bits, qk_backend)
        else:
            qf = q
            kf = k
            if mode == "train" and quant.enabled and quant.quantize_attention:
                qf = Q.fake_quant(q, quant.attn_act_bits)
                kf = Q.fake_quant(k, quant.attn_act_bits)
            scores = _scores_float(qf, _gqa_expand(kf, h) if expand else kf, sdt)
        t_k = k.shape[1]  # == s for self-attn; encoder length for cross
        mask = _mask(s, t_k, 0, causal, window)
        scores = scores.astype(sdt) / jnp.sqrt(sdt(dh)) + mask[None, None].astype(sdt)
        probs = jax.nn.softmax(scores, axis=-1)
        if use_int:
            v_s = _gqa_expand(v_m, h) if expand else v_m
            ctx = _pv_int(probs.astype(jnp.float32), v_s, v_sc, v_off)
        else:
            if mode == "train" and quant.enabled and quant.quantize_attention:
                probs = Q.fake_quant(probs, quant.attn_act_bits)
            ctx = _pv_float(probs, _gqa_expand(v, h) if expand else v, x.dtype)
        if cache is not None and kv_override is None:
            if not quantized:
                k_m = k.astype(cache["k"].dtype)
                v_m = v.astype(cache["v"].dtype)
                k_sc = v_sc = k_off = v_off = None
            elif not use_int:
                k_sc, k_off = _calibrate_rows(k)
                v_sc, v_off = _calibrate_rows(v)
                k_m = _quantize_to_cache(k, k_sc, k_off)
                v_m = _quantize_to_cache(v, v_sc, v_off)
            new_cache = _write_prefill_cache(
                cache, k_m, v_m, s, cache_len, windowed,
                k_sc, k_off, v_sc, v_off,
            )
    else:
        # ---- single-token decode over the cache --------------------------
        # ``pos`` is per-row: every slot advances its own cursor, so a packed
        # continuous-batching batch mixes requests at unrelated positions.
        pos = jnp.broadcast_to(jnp.reshape(cache["pos"], (-1,)), (b,))  # (B,)
        slot = pos % cache_len if windowed else pos
        if quantized:
            k_sc, k_off = cache["k_scale"], cache["k_offset"]
            v_sc, v_off = cache["v_scale"], cache["v_offset"]
            if use_binary:
                # stream ONE packed row: binarize on the fixed prefill grid
                k_m = _binarize_to_cache(k, k_sc, k_off)
            else:
                k_m = _quantize_to_cache(k, k_sc, k_off)
            v_m = _quantize_to_cache(v, v_sc, v_off)
        else:
            k_m = k.astype(cache["k"].dtype)
            v_m = v.astype(cache["v"].dtype)
        row_write = jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0)
        )
        new_k = row_write(cache["k"], k_m, slot)
        new_v = row_write(cache["v"], v_m, slot)
        new_cache = dict(cache, k=new_k, v=new_v, pos=cache["pos"] + 1)

        t = cache_len
        posc = pos[:, None]  # (B, 1)
        if windowed:
            # absolute position held by slot j after writing at `slot`
            j = jnp.arange(t)[None, :]
            slot_abs = j + t * ((posc - j) // t)
            valid = slot_abs >= 0
            rel_ok = slot_abs > posc - cfg.window_size  # ring holds exactly W
            valid &= rel_ok & (slot_abs <= posc)
        else:
            valid = jnp.arange(t)[None, :] <= posc
            if window:
                valid &= jnp.arange(t)[None, :] > posc - window
        expand = cfg.gqa_mode == "expand"
        if use_int and use_binary:
            scores = _scores_binary(
                q, new_k.transpose(0, 2, 1, 3), k_sc, k_off, dh, "attn.qk", qk_backend
            )
        elif use_int:
            k_s = _gqa_expand(new_k, h) if expand else new_k
            scores = _scores_int(q, k_s, k_sc, k_off, quant.attn_act_bits, qk_backend)
        else:
            src_k = new_k
            if quantized:
                src_k = _dequantize_from_cache(src_k, k_sc, k_off, x.dtype)
            scores = _scores_float(q, _gqa_expand(src_k, h) if expand else src_k)
        scores = scores / jnp.sqrt(jnp.float32(dh))
        scores = jnp.where(valid[:, None, None, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        if use_int:
            v_s = _gqa_expand(new_v, h) if expand else new_v
            ctx = _pv_int(probs, v_s, v_sc, v_off)
        else:
            src_v = new_v
            if quantized:
                src_v = _dequantize_from_cache(src_v, v_sc, v_off, x.dtype)
            ctx = _pv_float(probs, _gqa_expand(src_v, h) if expand else src_v, x.dtype)

    out = L.qlinear(
        p["o"], _merge_heads(ctx).astype(x.dtype), quant, mode, name="attn.o"
    )
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek v2/v3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank:
        p["q_down"] = L.init_linear(ks[0], d, m.q_lora_rank)
        p["q_norm_lora"] = jnp.zeros((m.q_lora_rank,), jnp.float32)
        p["q_up"] = L.init_linear(ks[1], m.q_lora_rank, h * qd)
    else:
        p["q_proj"] = L.init_linear(ks[1], d, h * qd)
    p["kv_down"] = L.init_linear(ks[2], d, m.kv_lora_rank)
    p["kv_norm"] = jnp.zeros((m.kv_lora_rank,), jnp.float32)
    p["k_rope"] = L.init_linear(ks[3], d, m.qk_rope_dim)
    p["k_up"] = L.init_linear(ks[4], m.kv_lora_rank, h * m.qk_nope_dim)
    p["v_up"] = L.init_linear(ks[5], m.kv_lora_rank, h * m.v_head_dim)
    p["o"] = L.init_linear(ks[6], h * m.v_head_dim, d, scale=0.5)
    return p


def init_mla_cache(batch: int, max_len: int, cfg: ArchConfig) -> dict:
    """Latent cache with per-row ``pos`` / calibration (see init_kv_cache)."""
    m = cfg.mla
    q = cfg.quant
    base = {
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), jnp.bfloat16),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if q.enabled and q.kv_cache_bits in (4, 8):
        base.update(
            ckv=jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.int8),
            ckv_scale=jnp.ones((batch,), jnp.float32),
            ckv_offset=jnp.zeros((batch,), jnp.float32),
        )
    else:
        base["ckv"] = jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.bfloat16)
    return base


def _mla_q(p, x, cfg, mode, positions):
    """Project queries -> (q_nope (B,S,H,dn), q_rope (B,S,H,dr))."""
    m, h = cfg.mla, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    if m.q_lora_rank:
        qc = L.qlinear(p["q_down"], x, cfg.quant, mode, name="attn.q_down")
        qc = L.rmsnorm(p["q_norm_lora"], qc, cfg.norm_eps)
        q = L.qlinear(p["q_up"], qc, cfg.quant, mode, name="attn.q_up")
    else:
        q = L.qlinear(p["q_proj"], x, cfg.quant, mode, name="attn.q")
    q = q.reshape(*x.shape[:-1], h, qd)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    mode: str,
    positions: jax.Array,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """MLA mixer.  Prefill/train run the decompressed form; decode runs the
    *absorbed* form over the compressed (quantized) latent cache — the
    latent cache is both the memory win (kv_lora + rope per token instead of
    2*H*dh) and the right operand of the serving act x act QMMs."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    quant = cfg.quant
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))

    q_nope, q_rope = _mla_q(p, x, cfg, mode, positions)
    ckv = L.qlinear(p["kv_down"], x, quant, mode, name="attn.kv_down")
    ckv = L.rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = L.qlinear(p["k_rope"], x, quant, mode, name="attn.k_rope")  # (B,S,dr)
    k_rope = L.rope(k_rope, positions, cfg.rope_theta)

    decode = cache is not None and s == 1
    if cache is not None:
        # per-row cursor: slots may sit at different sequence positions
        pos = jnp.broadcast_to(jnp.reshape(cache["pos"], (-1,)), (b,))
        quantized = "ckv_scale" in cache
        row_write = jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0)
        )
        if quantized:
            if s > 1:
                sc, off = _calibrate_rows(ckv)
            else:
                sc = jnp.broadcast_to(jnp.reshape(cache["ckv_scale"], (-1,)), (b,))
                off = jnp.broadcast_to(jnp.reshape(cache["ckv_offset"], (-1,)), (b,))
            c_m = _quantize_to_cache(ckv, sc, off)
            # rope slot dtype derives from the cache leaf (never a literal:
            # a write/init mismatch is exactly the PR 6 drift class)
            r_u = k_rope.astype(cache["k_rope"].dtype)
            if decode:
                new_ckv = row_write(cache["ckv"], c_m, pos)
                new_rope = row_write(cache["k_rope"], r_u, pos)
            else:
                # prefill contract: fresh/uniform cache rows (row-0 cursor)
                new_ckv = jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], c_m, pos[0], 1
                )
                new_rope = jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], r_u, pos[0], 1
                )
            cache = dict(
                cache,
                ckv=new_ckv,
                ckv_scale=sc,
                ckv_offset=off,
                k_rope=new_rope,
                pos=cache["pos"] + s,
            )
        else:
            c_u = ckv.astype(cache["ckv"].dtype)
            r_u = k_rope.astype(cache["k_rope"].dtype)
            if decode:
                new_ckv = row_write(cache["ckv"], c_u, pos)
                new_rope = row_write(cache["k_rope"], r_u, pos)
            else:
                new_ckv = jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], c_u, pos[0], 1
                )
                new_rope = jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], r_u, pos[0], 1
                )
            cache = dict(cache, ckv=new_ckv, k_rope=new_rope, pos=cache["pos"] + s)

    if decode:
        # ---- absorbed decode over the latent cache ----
        t = cache["ckv"].shape[1]
        w_uk = p["k_up"]["w"] if "w" in p["k_up"] else None
        if w_uk is None:
            # serving params: dequantize the tiny up-projections once per
            # step (kv_lora x H*dn — weight-bits packed); absorbed matmuls
            # then run against the integer latent cache.
            w_uk = _serving_dense(p["k_up"], m.kv_lora_rank, quant)
            w_uv = _serving_dense(p["v_up"], m.kv_lora_rank, quant)
        else:
            w_uv = p["v_up"]["w"]
        w_uk_h = w_uk.reshape(m.kv_lora_rank, h, m.qk_nope_dim)
        # q_absorbed[b,1,h,r] = sum_dn q_nope[b,1,h,dn] * w_uk[r,h,dn]
        q_abs = jnp.einsum(
            "bshd,rhd->bshr", q_nope.astype(jnp.float32), w_uk_h.astype(jnp.float32)
        )
        quantized = "ckv_scale" in cache
        lat_backend = quant.backend_for("attn.qk_latent")
        if quantized and quant.quantize_attention and (
            _binary_scores_site(quant, "attn.qk_latent") is not None
        ):
            scores_lat = _scores_binary_latent(
                q_abs,
                cache["ckv"],
                cache["ckv_scale"],
                cache["ckv_offset"],
                "attn.qk_latent",
                lat_backend,
            )
        elif quantized and quant.quantize_attention:
            scores_lat = _scores_int_latent(
                q_abs,
                cache["ckv"],
                cache["ckv_scale"],
                cache["ckv_offset"],
                quant.attn_act_bits,
                lat_backend,
            )
        else:
            ckv_all = cache["ckv"]
            if quantized:
                ckv_all = _dequantize_from_cache(
                    ckv_all, cache["ckv_scale"], cache["ckv_offset"], jnp.float32
                )
            scores_lat = jnp.einsum(
                "bshr,btr->bhst", q_abs, ckv_all.astype(jnp.float32)
            )
        scores_rope = jnp.einsum(
            "bshd,btd->bhst",
            q_rope.astype(jnp.float32),
            cache["k_rope"].astype(jnp.float32),
        )
        scores = (scores_lat + scores_rope) * scale
        valid = jnp.arange(t)[None, :] < jnp.reshape(cache["pos"], (-1, 1))
        scores = jnp.where(valid[:, None, None, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)  # (B,H,1,T)
        if quantized and quant.quantize_attention:
            ctx_lat = _pv_int_latent(
                probs, cache["ckv"], cache["ckv_scale"], cache["ckv_offset"]
            )
        else:
            ckv_all = cache["ckv"]
            if quantized:
                ckv_all = _dequantize_from_cache(
                    ckv_all, cache["ckv_scale"], cache["ckv_offset"], jnp.float32
                )
            ctx_lat = jnp.einsum("bhst,btr->bshr", probs, ckv_all.astype(jnp.float32))
        w_uv_h = w_uv.reshape(m.kv_lora_rank, h, m.v_head_dim)
        ctx = jnp.einsum("bshr,rhd->bshd", ctx_lat, w_uv_h.astype(jnp.float32))
        out = L.qlinear(
            p["o"],
            ctx.reshape(b, s, h * m.v_head_dim).astype(x.dtype),
            quant,
            mode,
            name="attn.o",
        )
        return out, cache

    # ---- decompressed prefill / train ----
    sdt = jnp.bfloat16 if cfg.attn_scores_dtype == "bf16" else jnp.float32
    k_nope = L.qlinear(
        p["k_up"], ckv, quant, mode, name="attn.k_up"
    ).reshape(b, s, h, m.qk_nope_dim)
    v = L.qlinear(
        p["v_up"], ckv, quant, mode, name="attn.v_up"
    ).reshape(b, s, h, m.v_head_dim)
    if mode == "train" and quant.enabled and quant.quantize_attention:
        q_nope = Q.fake_quant(q_nope, quant.attn_act_bits)
        k_nope = Q.fake_quant(k_nope, quant.attn_act_bits)
    scores = (
        jnp.einsum("bshd,bthd->bhst", q_nope.astype(sdt), k_nope.astype(sdt))
        + jnp.einsum("bshd,btd->bhst", q_rope.astype(sdt), k_rope.astype(sdt))
    ) * sdt(scale)
    mask = _mask(s, s, positions[0, 0] * 0, cfg.causal, 0)
    scores = scores + mask[None, None].astype(sdt)
    probs = jax.nn.softmax(scores, axis=-1)
    if mode == "train" and quant.enabled and quant.quantize_attention:
        probs = Q.fake_quant(probs, quant.attn_act_bits)
    ctx = jnp.einsum("bhst,bthd->bshd", probs.astype(x.dtype), v)
    out = L.qlinear(
        p["o"], ctx.reshape(b, s, h * m.v_head_dim), quant, mode, name="attn.o"
    )
    return out, cache


def _pv_int_latent(p_probs, ckv_m, ckv_scale, ckv_offset):
    """Absorbed-MLA context as act x act QMM: ``P (B,H,S,T) @ ckv (B,T,R)``
    with heads folded into M (latent is head-shared).  Returns (B,S,H,R)."""
    b, h, s, t = p_probs.shape
    r = ckv_m.shape[-1]
    pm = jnp.clip(jnp.round(p_probs * 255.0), 0.0, 255.0)
    x1 = (pm - 128.0).astype(jnp.int8).transpose(0, 2, 1, 3).reshape(b, s * h, t)
    a1, g1 = jnp.float32(1.0 / 255.0), jnp.float32(128.0 / 255.0)
    x2 = ckv_m.astype(jnp.int8)  # (b, t, r)
    a2 = _per_row(ckv_scale, 3)
    g2 = _per_row(ckv_offset, 3) + 128.0 * a2
    xy = FA.default_int_matmul(x1, x2, 8, 8).astype(jnp.float32)
    row = jnp.sum(x1, axis=-1, dtype=jnp.int32)[..., None].astype(jnp.float32)
    col = jnp.sum(x2, axis=-2, dtype=jnp.int32)[..., None, :].astype(jnp.float32)
    out = xy * (a1 * a2) + (a1 * g2) * row + (g1 * a2) * col + g1 * g2 * t
    return out.reshape(b, s, h, r)


def _serving_dense(p: dict, k: int, quant: QuantConfig) -> jax.Array:
    """Materialize a small packed weight back to float (absorbed-path use)."""
    wq = Q.QuantTensor(
        mantissa=p["w_packed"],
        scale=p["w_scale"],
        offset=p["w_offset"],
        bits=quant.weight_bits,
        packed=True,
        packed_axis=0,
        length=k,
    )
    return wq.dequantize(jnp.float32)
