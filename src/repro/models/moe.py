"""Mixture-of-Experts FFN (deepseek-style: shared + routed top-k) — quant-aware.

Dispatch is capacity-based scatter/gather (GShard lineage): tokens are
sorted by expert, positioned within each expert's capacity buffer, and the
expert MMs run as one stacked batched matmul ``(E, C, D) x (E, D, F)`` —
the form that shards cleanly under pjit (experts over the ``model``/EP axis,
capacity over ``data``) and that the MoE-EP hillclimb re-schedules with
shard_map all-to-alls (EXPERIMENTS.md §Perf).

BETA integration: routed AND shared experts are binary-weight QMMs; the
router stays full-precision (tiny and accuracy-critical — the same rationale
as the paper's FP softmax).  Capacity overflow drops tokens (standard
GShard semantics; capacity_factor sizes the buffer).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, QuantConfig
from repro.core import flow_abstraction as FA
from repro.core import quantization as Q
from repro.models import layers as L

__all__ = ["init_moe", "moe_ffn", "expert_qlinear", "pack_experts_for_serving"]


# ---------------------------------------------------------------------------
# stacked expert linear (E, K, N)
# ---------------------------------------------------------------------------


def init_experts(key, n_experts: int, d_in: int, d_out: int, scale: float = 1.0):
    std = scale / (d_in**0.5)
    return {"w": jax.random.normal(key, (n_experts, d_in, d_out), jnp.float32) * std}


def pack_experts_for_serving(p: dict, quant: QuantConfig) -> dict:
    if not quant.enabled:
        return {"w": p["w"].astype(jnp.bfloat16)}
    wq = Q.binarize_weight(p["w"])  # scale per (E, 1, N)
    colsum = FA.weight_corrections(wq)  # (E, N)
    packed = wq.pack(axis=1)
    return {
        "w_packed": packed.mantissa,  # uint32 (E, K/32, N)
        "w_scale": packed.scale.astype(jnp.float32),
        "w_offset": packed.offset.astype(jnp.float32),
        "w_colsum": colsum.astype(jnp.int32),
    }


def expert_qlinear(p: dict, x: jax.Array, quant: QuantConfig, mode: str, k: int):
    """``x (E, C, K) @ W (E, K, N)`` per expert, in the execution mode.

    Serve mode always runs the MXU integer flow: the stacked-expert batched
    MM has no popcount/pallas counterpart, so ``backend="auto"`` and
    ``backend_overrides`` do not apply here (docs/qmm-engine.md)."""
    if mode == "float" or not quant.enabled:
        return jnp.einsum("eck,ekn->ecn", x, p["w"].astype(x.dtype))
    if mode == "train":
        if quant.prebinarize_gather:
            w_hat = p["w"]  # pre-binarized via the packed-gather STE
        else:
            w_hat = Q.fake_binarize_weight(p["w"])  # (E,K,N), scales (E,1,N)
        x_hat = Q.fake_quant(x, quant.act_bits)
        return jnp.einsum("eck,ekn->ecn", x_hat, w_hat.astype(x.dtype))
    # serve: integer batched MM through the flow abstraction
    wq = Q.QuantTensor(
        mantissa=p["w_packed"],
        scale=p["w_scale"],
        offset=p["w_offset"],
        bits=quant.weight_bits,
        packed=True,
        packed_axis=1,
        length=k,
    )
    # per-token (E, C, 1) calibration: each routed token keeps its own grid
    # so the quantization of one request's tokens never depends on which
    # other tokens share the expert buffer (capacity dropping still makes
    # MoE routing itself batch-dependent — this only fixes the numerics)
    x32 = x.astype(jnp.float32)
    lo = jnp.min(jax.lax.stop_gradient(x32), axis=-1, keepdims=True)
    hi = jnp.max(jax.lax.stop_gradient(x32), axis=-1, keepdims=True)
    sc = jnp.maximum((hi - lo) / float(2**quant.act_bits - 1), 1e-8)
    xq = Q.quantize_activation(x32, quant.act_bits, scale=sc, offset=lo)
    out = FA.qmm_flow(xq, wq, w_colsum=p["w_colsum"])  # colsum (E, N)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# the MoE block
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig) -> dict:
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e.n_routed), jnp.float32) * 0.02},
        "up": init_experts(ks[1], e.n_routed, d, e.d_expert_ff),
        "gate": init_experts(ks[2], e.n_routed, d, e.d_expert_ff),
        "down": init_experts(ks[3], e.n_routed, e.d_expert_ff, d, scale=0.5),
    }
    if e.n_shared:
        p["shared"] = L.init_ffn(ks[4], cfg.ffn_type, d, e.shared_ff)
    return p


def _route(logits: jax.Array, e, top_k: int):
    """Router scores -> (weights (T, k), experts (T, k)). fp32 throughout."""
    if e.router_scoring == "sigmoid":  # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20) * e.route_scale
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(scores, top_k)
    return w, idx


def moe_ffn(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    mode: str,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_load_balance_loss scalar)."""
    e = cfg.moe
    quant = cfg.quant
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    # --- router (full precision) ---
    logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32), p["router"]["w"].astype(jnp.float32)
    )
    weights, experts = _route(logits, e, e.top_k)  # (T, k)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)  # (E,)
    counts = jnp.zeros((e.n_routed,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    frac = counts / jnp.float32(t * e.top_k)
    aux = jnp.float32(e.n_routed) * jnp.sum(frac * probs_mean)

    # --- capacity-based dispatch ---
    tk = t * e.top_k
    capacity = int(max(1, round(e.capacity_factor * tk / e.n_routed)))
    flat_expert = experts.reshape(tk)
    flat_weight = weights.reshape(tk).astype(jnp.float32)
    flat_token = jnp.repeat(jnp.arange(t), e.top_k)

    order = jnp.argsort(flat_expert)
    se = flat_expert[order]
    st = flat_token[order]
    sw = flat_weight[order].astype(x.dtype)  # combine weights ride in bf16
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(tk) - first  # position within expert group
    keep = pos < capacity
    dest = jnp.where(keep, se * capacity + pos, e.n_routed * capacity)  # drop slot

    # gather tokens into (E*C [+1 drop], D)
    buf = jnp.zeros((e.n_routed * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(xf[st].astype(x.dtype))
    h_in = buf[: e.n_routed * capacity].reshape(e.n_routed, capacity, d)

    # --- stacked expert FFN (binary QMMs) ---
    up = expert_qlinear(p["up"], h_in, quant, mode, d)
    gate = expert_qlinear(p["gate"], h_in, quant, mode, d)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out_e = expert_qlinear(p["down"], h, quant, mode, e.d_expert_ff)

    # --- combine ---
    out_flat = out_e.reshape(e.n_routed * capacity, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = out_flat[dest] * sw[:, None]  # dropped -> slot E*C
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    combined = jnp.zeros((t, d), x.dtype).at[st].add(gathered)

    # --- shared experts (dense FFN, also binary) ---
    if "shared" in p:
        combined = combined + L.ffn(
            p["shared"], xf, cfg.ffn_type, quant, mode
        )

    return combined.reshape(b, s, d), aux
