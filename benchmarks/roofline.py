"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reproduces: no paper table — the TPU-side roofline accounting for the
serving claims.  Needs dry-run artifacts first:
    PYTHONPATH=src python -m repro.launch.dryrun --all
Run:        PYTHONPATH=src python benchmarks/roofline.py

QMM-backend mode (``repro.core.qmm_roofline``): place every *registered*
QMM backend (mxu / popcount / pallas / fused, plus anything added later)
against the memory-bandwidth roof using its registry ``traffic_model``,
and record the ``BENCH_qmm.json`` artifact:

    PYTHONPATH=src python benchmarks/roofline.py --qmm-out BENCH_qmm.json
    PYTHONPATH=src python benchmarks/roofline.py --smoke \
        --qmm-out artifacts/BENCH_qmm_ci.json      # CI cell, tiny shapes
    PYTHONPATH=src python benchmarks/roofline.py --validate BENCH_qmm.json

Per (arch x shape x mesh) cell:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
  memory term     = HLO_bytes_per_device / HBM_bw             [s]
  collective term = collective_bytes_per_device / link_bw     [s]

``cost_analysis()`` numbers are PER-DEVICE and partition-aware (calibrated:
a dp-sharded op reports global/dp, so TP-idle replication shows up as extra
per-device flops — exactly what a roofline should charge).  Scan bodies are
counted once by XLA; the dry-run's ``period_body`` record corrects this:
``corrected = raw + (n_periods - 1) * body``.

MODEL_FLOPS = 6*N_active*D tokens (train; includes backward) or
2*N_active*D (inference) + attention terms — the useful-work yardstick; the
ratio MODEL_FLOPS / (HLO_FLOPs * n_devices) exposes remat/replication waste.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus links; cross-pod DCN is slower but the pod axis
is DP-only by design).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def model_flops(rec: dict) -> float:
    """Analytical useful FLOPs for the cell (global, all devices)."""
    n = rec["active_params"]
    b, s = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n * b * s  # fwd 2ND + bwd 4ND
    if rec["kind"] == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b  # decode: one token per sequence


def corrected(rec: dict, key: str) -> float:
    raw = rec["cost"]["flops"] if key == "flops" else rec["cost"]["bytes_accessed"]
    body = rec.get("period_body") or {}
    if "error" in body or not body:
        return float(raw or 0.0)
    nper = body.get("n_periods", 0)
    bval = body.get("flops" if key == "flops" else "bytes_accessed", 0.0)
    return float(raw or 0.0) + max(nper - 1, 0) * float(bval or 0.0)


def corrected_collective_bytes(rec: dict) -> float:
    raw = rec["collectives"]["total_bytes"]
    body = rec.get("period_body") or {}
    if "error" in body or not body or not isinstance(body.get("collectives"), dict):
        return float(raw)
    nper = body.get("n_periods", 0)
    return float(raw) + max(nper - 1, 0) * float(body["collectives"]["total_bytes"])


def analyze(rec: dict) -> dict:
    n_dev = 1
    for v in rec.get("mesh_shape", {}).values():
        n_dev *= v
    flops_dev = corrected(rec, "flops")
    bytes_dev = corrected(rec, "bytes_accessed")
    coll_dev = corrected_collective_bytes(rec)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    ratio = mf / (flops_dev * n_dev) if flops_dev else 0.0
    bound = max(terms.values())
    # roofline fraction: useful work per device-second at the binding limit
    useful_frac = (mf / n_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "cell": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}",
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * n_dev,
        "useful_ratio": ratio,
        "roofline_fraction": useful_frac,
        "n_devices": n_dev,
    }


def load_records(mesh: str = "single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        recs.append(rec)
    return recs


def run() -> list:
    rows = []
    for mesh in ("single", "multi"):
        for rec in load_records(mesh):
            if rec.get("status") == "skip":
                rows.append(
                    {
                        "name": f"roofline/{rec['arch']}/{rec['shape']}/{mesh}",
                        "us_per_call": 0.0,
                        "derived": f"SKIP ({rec['reason'][:60]})",
                    }
                )
                continue
            if rec.get("status") != "ok":
                rows.append(
                    {
                        "name": f"roofline/{rec['arch']}/{rec['shape']}/{mesh}",
                        "us_per_call": 0.0,
                        "derived": f"status={rec.get('status')}",
                    }
                )
                continue
            a = analyze(rec)
            dom_t = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
            rows.append(
                {
                    "name": f"roofline/{a['cell']}",
                    "us_per_call": dom_t * 1e6,
                    "derived": (
                        f"compute={a['t_compute_s']:.3e}s"
                        f" memory={a['t_memory_s']:.3e}s"
                        f" coll={a['t_collective_s']:.3e}s"
                        f" dom={a['dominant']}"
                        f" useful={a['useful_ratio']:.2f}"
                        f" roofline_frac={a['roofline_fraction']:.2f}"
                    ),
                }
            )
    return rows


def run_qmm(smoke: bool = False, out: str | None = None) -> dict:
    """QMM-backend roofline over every registered backend; optional artifact."""
    from repro.core import qmm_roofline as R

    if smoke:
        doc = R.run_qmm_roofline(R.SMOKE_SHAPES, R.SMOKE_PRECISIONS, warmup=1, reps=1)
    else:
        doc = R.run_qmm_roofline()
    if out:
        R.save_qmm_bench(out, doc)
    return doc


def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "--smoke",
        action="store_true",
        help="QMM mode with tiny shapes / single rep (the CI cell)",
    )
    p.add_argument(
        "--qmm-out",
        metavar="PATH",
        help="run the QMM-backend roofline and write the BENCH_qmm.json artifact",
    )
    p.add_argument(
        "--validate",
        metavar="PATH",
        help="validate an existing BENCH_qmm.json against the schema and exit",
    )
    args = p.parse_args(argv)

    if args.validate:
        from repro.core import qmm_roofline as R

        doc = R.load_qmm_bench(args.validate)
        print(
            f"{args.validate}: ok — {len(doc['cells'])} cells, "
            f"backends {sorted({c['backend'] for c in doc['cells']})}"
        )
        return 0
    if args.smoke or args.qmm_out:
        from repro.core import qmm_roofline as R

        doc = run_qmm(smoke=args.smoke, out=args.qmm_out)
        print(R.format_table(doc))
        if args.qmm_out:
            print(f"wrote {args.qmm_out}")
        return 0
    # legacy mode: the dry-run-artifact roofline table
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
