"""int8 error-feedback gradient compression: payload + fidelity accounting.

Reproduces: no paper table — a systems extension (the BETA storage insight
applied to the cross-pod gradient fabric; EXPERIMENTS.md §Dist).
Run:        PYTHONPATH=src python benchmarks/compression_bench.py

The distributed-optimization trick for cross-pod DP (optim.compression):
measures (a) wire-byte reduction of the compressed all-reduce vs fp32, and
(b) gradient fidelity (cosine similarity + error-feedback residual decay)
on a real QAT gradient from the smoke BERT model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.optim import compression as C


def run() -> list:
    cfg = smoke_variant(get_config("bit-bert-base"))
    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    grads = jax.grad(lambda p: Z.loss_fn(p, {"tokens": tokens}, cfg, "train")[0])(
        params
    )

    leaves = jax.tree.leaves(grads)
    fp32_bytes = sum(g.size * 4 for g in leaves)
    int8_bytes = sum(g.size * 1 + 4 for g in leaves)  # payload + scale

    err = C.init_error_state(grads)
    cos_list = []
    resid_norms = []
    g_flat = jnp.concatenate([g.ravel() for g in leaves]).astype(jnp.float32)
    for step in range(3):
        qs, scales, resids = [], [], []
        new_err = []
        for g, e in zip(leaves, jax.tree.leaves(err)):
            q, s, r = C.compress(g, e)
            qs.append(C.decompress(q, s).ravel())
            new_err.append(r)
        deq = jnp.concatenate(qs)
        cos = float(
            jnp.dot(deq, g_flat)
            / (jnp.linalg.norm(deq) * jnp.linalg.norm(g_flat) + 1e-12)
        )
        cos_list.append(cos)
        resid_norms.append(
            float(jnp.sqrt(sum(jnp.sum(r * r) for r in new_err)))
        )
        err = jax.tree.unflatten(jax.tree.structure(grads), new_err)

    return [
        {
            "name": "compression/wire_bytes",
            "us_per_call": 0.0,
            "derived": f"fp32={fp32_bytes} int8={int8_bytes} "
            f"reduction={fp32_bytes/int8_bytes:.2f}x",
        },
        {
            "name": "compression/fidelity",
            "us_per_call": 0.0,
            "derived": f"cosine_step0={cos_list[0]:.4f} "
            f"residual_norms={[f'{r:.3e}' for r in resid_norms]}",
        },
    ]


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
