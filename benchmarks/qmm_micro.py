"""QMM engine micro-benchmarks (measured on this container's CPU).

Reproduces the engine-level evidence behind the paper's §III-C claims
(Table II / Fig. 5 use the calibrated hardware model; this file measures
the *software* engine).  Run directly::

    PYTHONPATH=src python benchmarks/qmm_micro.py

Times the three integer backends and the naive dequantized-FP flow the
paper replaces, over BERT-base QMM shapes.  On CPU the absolute numbers
reflect this host, but three claims are checked *structurally*:

1. the abstracted flow (integer MM + rank-1 epilogue) beats the naive
   dequantize-then-FP32-matmul flow it replaces,
2. both QMM types (act x weight, act x act) run through one engine at
   every activation precision, and
3. the autotuned dispatcher (core.dispatch) picks a backend whose measured
   time matches the best candidate (chosen-vs-best parity rows): parity =
   t_chosen / t_best, 1.00 meaning the cache picked the true winner.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import flow_abstraction as FA
from repro.core import qmm as QE
from repro.core import quantization as Q


def _time(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


@jax.jit
def _naive(xq, wq):
    return FA.qmm_dequant_reference(xq, wq)


@functools.partial(jax.jit, static_argnames=("backend",))
def _flow(xq, wq, colsum, backend="mxu"):
    return QE.qmm(xq, wq, backend=backend, w_colsum=colsum)


@functools.partial(jax.jit, static_argnames=("backend",))
def _flow_nocs(xq, wq, backend="mxu"):
    return QE.qmm(xq, wq, backend=backend)


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    m, k, n = 128, 768, 3072  # BERT-base FFN-up QMM
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))

    for act_bits in (1, 8):
        xq = Q.quantize_activation(x, act_bits)
        wq = Q.binarize_weight(w)
        colsum = FA.weight_corrections(wq)
        t_naive = _time(_naive, xq, wq)
        t_flow = _time(_flow, xq, wq, colsum)
        rows.append(
            {
                "name": f"qmm_micro/act_weight/W1A{act_bits}",
                "us_per_call": t_flow,
                "derived": f"naive_fp={t_naive:.0f}us flow_int={t_flow:.0f}us "
                f"speedup={t_naive/max(t_flow,1e-9):.2f}x",
            }
        )

    # act x act (the QMM type prior accelerators lack): Q @ K^T shape
    a = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
    for act_bits in (4, 8):
        aq = Q.quantize_activation(a, act_bits)
        bq = Q.quantize_activation(b, act_bits)
        t_naive = _time(_naive, aq, bq)
        t_flow = _time(_flow_nocs, aq, bq)
        rows.append(
            {
                "name": f"qmm_micro/act_act/A{act_bits}xA{act_bits}",
                "us_per_call": t_flow,
                "derived": f"naive_fp={t_naive:.0f}us flow_int={t_flow:.0f}us",
            }
        )

    # popcount (DPU analogue) vs unpack->int8 dot, 1-bit x 1-bit
    xb = Q.quantize_activation(x, 1)
    wq = Q.binarize_weight(w)
    t_pop = _time(functools.partial(_flow_nocs, backend="popcount"), xb, wq)
    t_mxu = _time(functools.partial(_flow_nocs, backend="mxu"), xb, wq)
    rows.append(
        {
            "name": "qmm_micro/backends/popcount_vs_mxu",
            "us_per_call": t_pop,
            "derived": f"popcount={t_pop:.0f}us mxu={t_mxu:.0f}us",
        }
    )

    rows.extend(_dispatch_parity_rows(rng))
    return rows


def _dispatch_parity_rows(rng) -> list:
    """Chosen-vs-best parity of the autotuned dispatcher.

    For a grid of (M, precision) cells, let the autotune cache pick a
    backend, then independently re-time every candidate; report
    ``parity = t_chosen / t_best`` (1.00 = the cache picked the true
    winner; small noise-driven excursions above 1 are expected).
    """
    rows = []
    cache = dispatch.AutotuneCache()
    k, n = 768, 768  # BERT-base attention-out QMM column
    for m, act_bits in ((8, 1), (8, 8), (256, 1), (256, 8)):
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        xq = Q.quantize_activation(x, act_bits)
        wq = Q.binarize_weight(w)
        # same conditions the tuner timed under: packed weights, colsum folded
        colsum = FA.weight_corrections(wq)
        wq = wq.pack(axis=0)
        chosen = cache.choose(m, k, n, act_bits, 1)
        timings = {
            b: _time(functools.partial(_flow, backend=b), xq, wq, colsum)
            for b in dispatch.candidate_backends(m, k, n, act_bits, 1)
        }
        best = min(timings, key=timings.get)
        parity = timings[chosen] / timings[best]
        rows.append(
            {
                "name": f"qmm_micro/dispatch/M{m}_W1A{act_bits}",
                "us_per_call": timings[chosen],
                "derived": (
                    f"chosen={chosen} best={best} parity={parity:.2f} "
                    + " ".join(f"{b}={t:.0f}us" for b, t in sorted(timings.items()))
                ),
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
