"""Table I reproduction: FPGA resource breakdown from the datapath structure.

Reproduces: paper Table I (LUT/FF/BRAM/DSP budget on ZCU102).
Run:        PYTHONPATH=src python benchmarks/table1_resources.py

BETA's LUT/FF/BRAM/DSP budget follows from its structural parameters; the
model below derives each Table I row from (N, J, precision modes) and
first-principle per-PE costs, calibrated once on the DPU row:

* DPU LUTs: J PEs x N DPUs; a PE is an 8-bit configurable multiplier-packer
  (~4-input-LUT cost ~ 38/PE fitted) + compressor tree (3:2 CSA per level,
  ~J/2 compressors of 8 LUTs at level 0, halving up; + carry-select adder).
* Buffers: compute buffer holds both operand tiles (2 x 128x4096b) + binary
  weight buffer — BRAM36 count = bits / 36Kb.
* VPU: 64 DSP48s (the paper's choice) + control LUTs.

Reported as modeled vs paper; the point is that the breakdown *follows from
the architecture*, supporting the cycle model used for Table II/Fig 5.
"""

from __future__ import annotations

import math

PAPER = {
    "dpu_lut": 154_000,
    "dpu_ff": 49_000,
    "buffer_bram": 456,
    "other_qmm_lut": 21_000,
    "vpu_dsp": 64,
    "total_lut": 191_000,
    "total_bram": 543,
    "total_dsp": 64,
}


#: per-PE costs fitted ONCE on the DPU row, then the scaling in (N, J) is
#: structural.  A multi-precision packing PE (Fig. 4: 8b output register,
#: packing mux, bit-serial control) is ~290 LUT / ~95 FF — consistent with
#: comparable multi-precision bit-serial PEs in the literature.
_PE_LUT = 290
_PE_FF = 95


def model_resources(n_dpu: int = 2, j: int = 256) -> dict:
    pes = n_dpu * j
    # compressor-tree loop: 3:2 CSAs halving per level (8 LUT each) + final
    # carry-select adder (~200 LUT per DPU)
    tree_lut = sum((j >> l) * 8 for l in range(1, int(math.log2(j)) + 1)) * n_dpu
    csa_lut = 200 * n_dpu
    dpu_lut = _PE_LUT * pes + tree_lut + csa_lut
    dpu_ff = _PE_FF * pes
    # on-chip buffers: compute buffer holds whole operand matrices (§III-C),
    # SHARED by the DPUs (they consume the same tile, different output
    # columns): acts 128 x 3072 x 8b double-buffered + binary weight buffer
    # 3072 x 3072 x 1b -> BRAM36 = bits/36Kb (+5% control slack)
    act_bits = 2 * 128 * 3072 * 8
    weight_bits = 3072 * 3072
    bram = math.ceil((act_bits + weight_bits) / 36864 * 1.05)
    return {
        "dpu_lut": dpu_lut,
        "dpu_ff": dpu_ff,
        "buffer_bram": bram,
        "vpu_dsp": 64,
    }


def run() -> list:
    m = model_resources()
    rows = []
    for key in ("dpu_lut", "dpu_ff", "buffer_bram", "vpu_dsp"):
        ref = PAPER[key]
        err = abs(m[key] - ref) / ref * 100
        rows.append(
            {
                "name": f"table1/{key}",
                "us_per_call": 0.0,
                "derived": f"modeled={m[key]} paper={ref} err={err:.0f}%",
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
