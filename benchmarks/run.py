"""Benchmark orchestrator — one module per paper table/figure.

Run:        PYTHONPATH=src python benchmarks/run.py
Per-module invocations and an index: benchmarks/README.md.

Prints ``name,us_per_call,derived`` CSV:

  table1_resources   Table I   FPGA resource breakdown (structural model)
  table2_comparison  Table II  throughput / power / GOPS/W vs paper
  fig5_tradeoff      Fig. 5    precision <-> efficiency trade-off
  qmm_micro          (engine)  measured QMM backend micro-benchmarks
  compression_bench  (dist)    int8 error-feedback gradient all-reduce
  roofline           §Roofline 3-term analysis from dry-run artifacts
"""

from __future__ import annotations

import os
import sys
import traceback

# allow `python benchmarks/run.py` from the repo root (sys.path[0] is the
# benchmarks dir itself in that case, hiding the package)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (
        compression_bench,
        fig5_tradeoff,
        qmm_micro,
        roofline,
        table1_resources,
        table2_comparison,
    )

    modules = [
        ("table1", table1_resources),
        ("table2", table2_comparison),
        ("fig5", fig5_tradeoff),
        ("qmm_micro", qmm_micro),
        ("compression", compression_bench),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for r in mod.run():
                print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.00,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
