"""Open-loop serving benchmark -> BENCH_serve.json (perf trajectory).

    PYTHONPATH=src python benchmarks/serve_bench.py \
        --arch granite-8b --smoke --n-requests 16 --rate 8 \
        --out BENCH_serve.json

Drives the continuous-batching ``ServeEngine`` with the seeded Poisson
traffic generator (runtime.traffic) and persists requests/sec plus p50/p99
token latency.  The workload is fully determined by the CLI config, so the
committed ``BENCH_serve.json`` is a trajectory artifact: any PR touching
the serving hot path reruns the same command and diffs the numbers
(absolute values are host-dependent; the trajectory is what matters).

Latency accounting: token latency = time-to-first-token measured from the
request's *arrival* (queueing delay included — this is an open-loop bench)
plus every inter-token gap; TTFT percentiles are reported separately.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config, list_configs
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.faults import parse_fault_plan
from repro.runtime.serve_loop import ServeEngine
from repro.runtime.traffic import TrafficConfig, generate_requests, save_bench, summarize_bench


def run_bench(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    plan = parse_fault_plan(args.fault_plan)
    params = Z.init_params(jax.random.PRNGKey(args.seed), cfg)
    serving = Z.prepare_serving_params(params, cfg)
    engine = ServeEngine(
        cfg,
        serving,
        batch_slots=args.slots,
        max_len=args.max_len,
        seed=args.seed,
        autotune_cache_path=args.autotune_cache,
        fault_plan=plan,
        snapshot_every=args.snapshot_every,
        snapshot_dir=args.snapshot_dir,
    )
    tc = TrafficConfig(
        n_requests=args.n_requests,
        rate_rps=args.rate,
        prompt_len=(args.prompt_min, args.prompt_max),
        new_tokens=(args.new_min, args.new_max),
        temperature=args.temperature,
        deadline_s=args.deadline_s,
        seed=args.seed,
    )
    requests = generate_requests(tc, cfg.vocab_size)

    if args.warmup:
        # compile prefill/decode outside the measured window — with faults
        # suspended, so the chaos (and any demotion it triggers) lands
        # entirely inside the measured run whose events feed availability
        warm = generate_requests(
            TrafficConfig(n_requests=1, rate_rps=0.0, prompt_len=tc.prompt_len,
                          new_tokens=(2, 2), seed=tc.seed + 1),
            cfg.vocab_size,
        )
        engine.fault_plan = parse_fault_plan(None)
        engine.run(warm)
        engine.fault_plan = plan

    t0 = time.perf_counter()
    done = engine.run(requests)
    wall = time.perf_counter() - t0

    config = {
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "batch_slots": args.slots,
        "max_len": args.max_len,
        "quant_mode": cfg.quant.mode_name,
        "traffic": tc.to_dict(),
        "fault_plan": plan.to_dict() if not plan.is_noop() else None,
    }
    summary = summarize_bench(done, wall, config, events=engine.last_events)
    # zero LOST requests: every request reaches a terminal state, and every
    # successful one carries its full output (failures/deadline misses are
    # recorded in the availability block, never dropped silently)
    assert all(r.state in ("ok", "failed", "deadline") for r in done)
    assert all(
        len(r.output) == r.max_new_tokens for r in done if r.state == "ok"
    )
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-8b", choices=list(list_configs()))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0, help="Poisson arrivals/s; <=0 = all at t0")
    ap.add_argument("--prompt-min", type=int, default=4)
    ap.add_argument("--prompt-max", type=int, default=12)
    ap.add_argument("--new-min", type=int, default=4)
    ap.add_argument("--new-max", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warmup", action="store_true", default=True)
    ap.add_argument("--no-warmup", dest="warmup", action="store_false")
    ap.add_argument("--autotune-cache", default=None)
    ap.add_argument("--fault-plan", default=None,
                    help="JSON FaultPlan (runtime.faults) for a chaos run, e.g. "
                         "'{\"decode_fail_ticks\": [1]}'")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (seconds from arrival)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot engine state every K decode ticks (0 = off)")
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    summary = run_bench(args)
    save_bench(args.out, summary)
    print(json.dumps(summary, indent=2, sort_keys=True))
    print(f"[serve_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
