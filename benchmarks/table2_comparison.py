"""Table II reproduction: BETA vs FP-32/FIX-16 baselines vs CPU.

Reproduces: paper Table II (throughput / power / GOPS/W comparison).
Run:        PYTHONPATH=src python benchmarks/table2_comparison.py

Columns reproduced from the calibrated structural model (core.energy_model):
throughput (GOPS), power (W), energy efficiency (GOPS/W) for the three
benchmark models (BiT / BinaryBERT / BiBERT, all BERT-base @ W1A1), the two
same-FPGA baselines, and a live-measured CPU row (this container's CPU
running the same BERT-base QMM inventory in fp32 jnp — the Table II CPU
column used an i7-10510U; ours is reported as measured).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy_model as em
from repro.core.precision import MODES


def _cpu_bert_gops(seq: int = 128, reps: int = 3) -> float:
    """Measured fp32 GOPS of one BERT-base QMM inventory on this CPU."""
    wl = em.bert_base_qmm_workload(seq=seq)
    rng = np.random.default_rng(0)
    mats = []
    for s in wl:
        a = jnp.asarray(rng.standard_normal((s.m, s.k), dtype=np.float32))
        b = jnp.asarray(rng.standard_normal((s.k, s.n), dtype=np.float32))
        mats.append((a, b, s.count))

    @jax.jit
    def run_all(mats_flat):
        outs = []
        for a, b in mats_flat:
            outs.append(jnp.sum(a @ b))
        return jnp.stack(outs).sum()

    flat = [(a, b) for a, b, _ in mats]
    run_all(flat).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run_all(flat).block_until_ready()
    per_pass = (time.perf_counter() - t0) / reps
    # scale by per-shape counts (the jit pass runs each unique QMM once)
    total_ops = 2.0 * sum(s.macs for s in wl)
    once_ops = 2.0 * sum(s.m * s.k * s.n for s in wl)
    est_time = per_pass * (total_ops / once_ops)
    return total_ops / est_time / 1e9


def run() -> list:
    rows = []
    wl = em.bert_base_qmm_workload()
    mode = MODES["W1A1"]
    hw = em.ZCU102_BETA
    for name, oh in em.BENCHMARK_OVERHEADS.items():
        gops, t = em.throughput_gops(wl, mode, hw, oh)
        p = em.power_w(wl, mode, hw, oh)
        eff = em.energy_efficiency(wl, mode, hw, oh)
        ref = em.PAPER_TABLE2[name]
        rows.append(
            {
                "name": f"table2/BETA/{name}",
                "us_per_call": t * 1e6,
                "derived": (
                    f"gops={gops:.1f}(paper {ref['gops']:.1f})"
                    f" power={p:.2f}W(paper {ref['power_w']:.2f})"
                    f" eff={eff:.1f}GOPS/W(paper {ref['gops_per_w']:.2f})"
                    f" err={(abs(eff-ref['gops_per_w'])/ref['gops_per_w'])*100:.2f}%"
                ),
            }
        )
    # FPGA baselines (reported; they define the paper's 91.86x / 17.21x klaims)
    bit = em.PAPER_TABLE2["BiT"]
    for name, ref in em.PAPER_TABLE2_BASELINES.items():
        rows.append(
            {
                "name": f"table2/baseline/{name}",
                "us_per_call": 0.0,
                "derived": (
                    f"gops={ref['gops']} eff={ref['gops_per_w']}GOPS/W"
                    f" beta_speedup={bit['gops']/ref['gops']:.2f}x"
                    f" beta_eff_gain={bit['gops_per_w']/ref['gops_per_w']:.2f}x"
                ),
            }
        )
    cpu = _cpu_bert_gops()
    rows.append(
        {
            "name": "table2/CPU/this-container-fp32",
            "us_per_call": 0.0,
            "derived": f"gops={cpu:.2f} (paper i7 row: 6.69)"
            f" beta_vs_this_cpu={bit['gops']/max(cpu,1e-9):.0f}x",
        }
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
