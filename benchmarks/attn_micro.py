"""Attention-scores micro-benchmark: the scores family on this host.

Measures every registered scores-family core (binary AND-popcount,
unpack->int8 MXU, unpack->f32) over attention-shaped problems and reports
chosen-vs-best parity of the autotuned dispatcher — the engine-level
evidence that "attn.qk -> binary" autotunes binary-vs-int-vs-float per
shape without ever changing numerics (all cores are bit-exact; parity is
pure speed).  Run directly::

    PYTHONPATH=src python benchmarks/attn_micro.py
    PYTHONPATH=src python benchmarks/attn_micro.py --smoke --out BENCH_attn.json
    PYTHONPATH=src python benchmarks/attn_micro.py --validate BENCH_attn.json

On CPU the absolute numbers reflect this host; the artifact records the
platform so readers can tell which regime the measured column holds in.
"""

from __future__ import annotations

import functools

import jax

from repro.core import attn_bench as AB
from repro.core import backend_registry, dispatch


def _dispatch_parity_rows() -> list:
    """Chosen-vs-best parity of the scores dispatcher.

    For a grid of attention shapes, let a fresh autotune cache pick a core,
    then independently re-time every candidate; parity = t_chosen / t_best
    (1.00 = the cache picked the true winner; small noise-driven excursions
    above 1 are expected).
    """
    rows = []
    cache = dispatch.AutotuneCache()
    for b, h, g, s, t, dh in AB.SMOKE_SHAPES + ((1, 8, 2, 1, 128, 64),):
        chosen = dispatch.choose_scores_backend(b, h, s, t, dh, cache=cache)
        q_planes = AB.make_planes(b, h, s, dh, seed=1)
        k_planes = AB.make_planes(b, g, t, dh, seed=2)
        timings = {}
        for name in backend_registry.backend_names(family="scores"):
            spec = backend_registry.get_backend(name)
            call = jax.jit(functools.partial(spec.run_scores, dh=dh))
            timings[name] = (
                dispatch._wallclock_timer(lambda: call(q_planes, k_planes))
                * 1e6
            )
        best = min(timings, key=timings.get)
        parity = timings[chosen] / timings[best]
        rows.append(
            {
                "name": f"attn_micro/dispatch/B{b}H{h}G{g}S{s}T{t}d{dh}",
                "us_per_call": timings[chosen],
                "derived": (
                    f"chosen={chosen} best={best} parity={parity:.2f} "
                    + " ".join(
                        f"{n}={v:.0f}us" for n, v in sorted(timings.items())
                    )
                ),
            }
        )
    return rows


def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "--smoke", action="store_true",
        help="one small shape (the CI cell) instead of the default grid",
    )
    p.add_argument(
        "--out", default="", help="write the BENCH_attn.json artifact here"
    )
    p.add_argument(
        "--validate",
        default="",
        help="validate an existing BENCH_attn.json against the schema and exit",
    )
    args = p.parse_args(argv)

    if args.validate:
        doc = AB.load_attn_bench(args.validate)
        print(
            f"{args.validate}: ok — {len(doc['cells'])} cells, "
            f"backends {doc['backends']}"
        )
        return 0

    shapes = AB.SMOKE_SHAPES if args.smoke else AB.DEFAULT_SHAPES
    doc = AB.run_attn_bench(shapes)
    print(AB.format_table(doc))
    if args.out:
        AB.save_attn_bench(args.out, doc)
        print(f"wrote {args.out}")
    for r in _dispatch_parity_rows():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
