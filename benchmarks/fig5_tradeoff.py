"""Fig. 5 reproduction: efficiency <-> accuracy trade-off across W1A{1,2,4,8}.

Reproduces: paper Fig. 5 (precision <-> efficiency/accuracy trade-off).
Run:        PYTHONPATH=src python benchmarks/fig5_tradeoff.py

Hardware side (throughput, GOPS/W): pure predictions of the calibrated
structural model — the paper's measured trend (throughput and efficiency
rise as activation precision drops) must come out of the datapath structure
(pack_factor + bit-serial), not per-point fits.

Accuracy side: the paper reports MNLI-m accuracy of pre-trained BiT /
BinaryBERT checkpoints, which don't exist in this offline container; the
accuracy column here comes from the QAT example (examples/precision_tradeoff
trains the same tiny LM at each precision) — the monotone accuracy drop with
fewer activation bits is the reproduced *shape* of Fig. 5.
"""

from __future__ import annotations

from repro.core import energy_model as em
from repro.core.precision import MODES


def run() -> list:
    rows = []
    wl = em.bert_base_qmm_workload()
    hw = em.ZCU102_BETA
    oh = em.BENCHMARK_OVERHEADS["BiT"]
    prev_eff = 0.0
    for name in ("W1A8", "W1A4", "W1A2", "W1A1"):
        mode = MODES[name]
        gops, t = em.throughput_gops(wl, mode, hw, oh)
        eff = em.energy_efficiency(wl, mode, hw, oh)
        rows.append(
            {
                "name": f"fig5/BiT/{name}",
                "us_per_call": t * 1e6,
                "derived": f"gops={gops:.1f} eff={eff:.1f}GOPS/W"
                f" monotone={'yes' if eff > prev_eff else 'NO'}",
            }
        )
        prev_eff = eff
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
