"""Generate the §Dry-run and §Roofline markdown tables from artifacts.

    PYTHONPATH=src python scripts/build_experiments_tables.py > artifacts/tables.md
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import analyze, model_flops  # noqa: E402


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(mesh, suffix=""):
    recs = {}
    tail = f"__{suffix}" if suffix else ""
    for path in sorted(glob.glob(f"artifacts/dryrun/*__{mesh}{tail}.json")):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if suffix and (len(parts) < 4 or parts[3] != suffix):
            continue
        if not suffix and len(parts) != 3:
            continue
        with open(path) as f:
            recs[(parts[0], parts[1])] = json.load(f)
    return recs


def dryrun_table(mesh):
    print(f"\n### Dry-run cells — {mesh} mesh\n")
    print("| arch | shape | status | step | HLO flops/dev | HLO bytes/dev | coll bytes/dev | args/dev | compile |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(load(mesh).items()):
        if r["status"] == "skip":
            print(f"| {arch} | {shape} | SKIP ({r['reason'][:40]}...) | | | | | | |")
            continue
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | {r['status']} | | | | | | |")
            continue
        from benchmarks.roofline import corrected, corrected_collective_bytes

        fl = corrected(r, "flops")
        by = corrected(r, "bytes_accessed")
        cb = corrected_collective_bytes(r)
        args = r["memory"]["argument_size_in_bytes"]
        print(
            f"| {arch} | {shape} | ok | {r.get('step','')} | {fl:.3e} | "
            f"{fmt_bytes(by)} | {fmt_bytes(cb)} | {fmt_bytes(args)} | {r.get('compile_s','')}s |"
        )


def roofline_table(mesh="single"):
    print(f"\n### Roofline — {mesh} mesh (per chip: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s link)\n")
    print("| arch | shape | compute [s] | memory [s] | collective [s] | dominant | MODEL_FLOPS | useful ratio | what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|")
    hints = []
    for (arch, shape), r in sorted(load(mesh).items()):
        if r["status"] != "ok":
            continue
        a = analyze(r)
        hint = {
            "memory": "smaller activation dtypes / fused attention / fewer remat passes",
            "collective": "sharding that avoids KV/operand gathers; overlap",
            "compute": "already compute-bound: higher MXU util / int8 datapath",
        }[a["dominant"]]
        print(
            f"| {arch} | {shape} | {a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} | "
            f"{a['t_collective_s']:.3e} | **{a['dominant']}** | {a['model_flops']:.3e} | "
            f"{a['useful_ratio']:.2f} | {hint} |"
        )


if __name__ == "__main__":
    dryrun_table("single")
    dryrun_table("multi")
    roofline_table("single")
