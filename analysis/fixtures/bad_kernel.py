"""Deliberately-broken toy kernel: every jaxpr invariant must fire on it.

Loaded by path (never on sys.path) from the verifier self-test and the unit
tests.  Each function reproduces one class of datapath bug the verifier
exists to catch; if a refactor of the taint walker stops detecting any of
them, ``python -m repro.analysis --self-test`` fails.
"""

import jax.numpy as jnp
from jax import lax


def leak_packed_to_float(packed):
    """INV-PACKED-FLOAT: treats uint32 bit-plane *storage* as numbers."""
    return packed.astype(jnp.float32) * 2.0


def accumulate_in_bf16(a_packed, b_packed):
    """INV-ACCUM-LOWFP: popcount accumulator rounded through bfloat16."""
    counts = lax.population_count(a_packed & b_packed)
    return jnp.sum(counts.astype(jnp.bfloat16), axis=-1)


def fused_kernel_lowfp(a_packed, b_packed):
    """INV-ACCUM-LOWFP at the kernel boundary: a Pallas kernel fed packed
    bit-planes finishes its accumulation in bfloat16 instead of returning an
    integer accumulator or an f32 fused epilogue."""
    import jax
    from jax.experimental import pallas as pl

    def kernel(a_ref, b_ref, o_ref):
        counts = lax.population_count(a_ref[...] & b_ref[...])
        o_ref[...] = jnp.sum(
            counts.astype(jnp.bfloat16), axis=-1, keepdims=True
        )

    m = a_packed.shape[0]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.bfloat16),
        interpret=True,
    )(a_packed, b_packed)


def binary_attn_lowfp(q_planes, k_planes):
    """INV-ACCUM-LOWFP on the attention-scores path: AND-popcount counts
    over packed rank-4 Q/K bit-planes accumulated through bfloat16 instead
    of int32 with an f32 epilogue exit."""
    joint = q_planes[:, :, :, None, :] & k_planes[:, :, None, :, :]
    counts = lax.population_count(joint)
    return jnp.sum(counts.astype(jnp.bfloat16), axis=-1)


def int_dot_low_precision(a, b):
    """INV-INT-DOT: int8 x int8 dot without preferred_element_type=int32
    accumulates in int8 and wraps after 128 / 127."""
    return jnp.dot(a, b)


def init_cache(batch, seq, d):
    return {
        "k": jnp.zeros((batch, seq, d), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def drifting_step(cache, x):
    """INV-CACHE-DTYPE: the PR 6 bug class — a step that writes the slot
    back in bfloat16 when init_cache allocated float32."""
    return dict(cache, k=cache["k"].astype(jnp.bfloat16))


def growing_step(cache, x):
    """INV-CACHE-SHAPE: appends instead of splicing into fixed capacity."""
    return dict(
        cache,
        k=jnp.concatenate([cache["k"], x[:, None, :]], axis=1),
        pos=cache["pos"] + 1,
    )
