"""Known-good lint fixture: the clean counterpart of every lint_bad.py hit.

The lint pass must report ZERO findings here — each function shows the
idiom the rule's fix hint prescribes.
"""

import jax
import jax.numpy as jnp
import numpy as np


def good_rng001(seed):
    rng = np.random.default_rng(seed)  # explicit Generator, no global state
    return rng.standard_normal(4)


def good_rng002(key):
    # the key is threaded in from the caller, never hardcoded here
    return jax.random.normal(key, (4,))


def good_rng002_eval_shape(fn):
    # shape-only trace: the key's value is never consumed (exempt)
    return jax.eval_shape(fn, jax.random.PRNGKey(0))


@jax.jit
def good_time001(x, t0):
    # callers own the clock; the traced function takes the timestamp as data
    return x + t0


def good_trace001(x):
    return jnp.where(jnp.any(x > 0), x, x * 2)  # traced select, no Python branch


def good_dtype001(x, cache):
    return x.astype(cache["k"].dtype)  # dtype derives from the target leaf


def good_mut001(x, acc=None):
    if acc is None:
        acc = []
    acc.append(x)
    return acc
