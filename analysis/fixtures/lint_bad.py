"""Known-bad lint fixture: every rule must fire at least once on this file.

Never imported — parsed only.  Lives outside ``src/`` so the production
lint sweep never sees it.  tests/test_analysis_lint.py and the CLI
``--self-test`` assert each rule id below is detected.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np


def bad_rng001():
    np.random.seed(0)  # RNG001: global process-wide RNG state
    return np.random.randn(4)  # RNG001


def bad_rng002():
    key = jax.random.PRNGKey(42)  # RNG002: hardcoded seed, not eval_shape
    return jax.random.normal(key, (4,))


@jax.jit
def bad_time001(x):
    t0 = time.time()  # TIME001: baked in as a constant at trace time
    return x + t0


def bad_trace001(x):
    if jnp.any(x > 0):  # TRACE001: Python branch on a traced reduction
        return x
    while jnp.max(x) < 1.0:  # TRACE001
        x = x * 2
    return x


def bad_dtype001(x):
    return x.astype(jnp.bfloat16)  # DTYPE001: hardcoded low-precision literal


def bad_mut001(x, acc=[]):  # MUT001: mutable default
    acc.append(x)
    return acc


def bad_mut001_kw(x, *, table={}):  # MUT001 (kw-only default)
    return table.get(x)
