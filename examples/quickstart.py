"""Quickstart: BETA's computation-flow abstraction + QMM engine in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's Fig. 2 example end-to-end: affine-quantized operands,
the naive full-precision flow, the abstracted integer flow, and the
engine's precision modes — then shows the packed-weight serving layout.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flow_abstraction as FA
from repro.core import packing
from repro.core import qmm as QE
from repro.core import quantization as Q
from repro.core.precision import MODES


def main() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((256, 64)).astype(np.float32))

    # 1) affine-quantize: activation -> alpha*X + gamma (W1A4 mode), weight
    #    -> sign-binary (the paper's (aA + g*1) x bW example)
    xq = Q.quantize_activation(x, bits=4)
    wq = Q.binarize_weight(w)
    print(f"activation: {xq.bits}-bit mantissa, scale={float(xq.scale):.4f}")
    print(f"weight:     {wq.bits}-bit mantissa, per-channel scales {wq.scale.shape}")

    # 2) the naive flow the paper replaces: dequantize -> FP matmul
    naive = FA.qmm_dequant_reference(xq, wq)

    # 3) the abstracted flow: integer MM + rank-1 corrections (exact!)
    flow = QE.qmm(xq, wq, backend="mxu")
    print("max |flow - naive| =", float(jnp.max(jnp.abs(flow - naive))))

    # 4) op accounting (Fig. 2): N^3 Op -> 2N^3 Iop + (3N^2+2) Op
    n = 64
    print("naive:", FA.op_counts_naive(n, n, n))
    print("flow: ", FA.op_counts_abstracted(n, n, n))

    # 5) engine modes (Fig. 4) — one datapath, four precisions
    for name, mode in MODES.items():
        xq_m = Q.quantize_activation(x, mode.act_bits)
        out = QE.qmm(xq_m, wq, backend="mxu", mode=mode)
        err = float(jnp.max(jnp.abs(out - x @ w)))
        print(f"{name}: pack_factor={mode.pack_factor} "
              f"bitserial={mode.bitserial_cycles} quant_err={err:.3f}")

    # 6) both QMM types: act x act (Q @ K^T) through the same engine
    q_ = Q.quantize_activation(jnp.asarray(rng.standard_normal((8, 64)), jnp.float32), 8)
    k_ = Q.quantize_activation(jnp.asarray(rng.standard_normal((64, 8)), jnp.float32), 8)
    print("act x act err:", float(jnp.max(jnp.abs(
        QE.qmm(q_, k_) - FA.qmm_dequant_reference(q_, k_)))))

    # 7) serving layout: weights bit-packed 32-to-a-word in HBM
    packed = wq.pack(axis=0)
    print(f"packed weights: {packed.mantissa.shape} uint32 "
          f"({w.size*4}B fp32 -> {packed.mantissa.size*4}B packed, "
          f"{w.size*4/(packed.mantissa.size*4):.0f}x)")


if __name__ == "__main__":
    main()
