"""Fig. 5's OTHER axis: accuracy vs activation precision, by QAT.

    PYTHONPATH=src python examples/precision_tradeoff.py [--steps 120]

Trains the SAME tiny LM at W1A1 / W1A2 / W1A4 / W1A8 and reports final
loss next to the calibrated hardware model's throughput/efficiency for that
mode — reproducing the trade-off the paper's Fig. 5 demonstrates on BETA
(efficiency rises, accuracy falls as activation bits shrink).
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, QuantConfig
from repro.core import energy_model as em
from repro.core.precision import MODES
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.runtime import train_loop as TL


def build_cfg(act_bits: int) -> ArchConfig:
    return ArchConfig(
        name=f"tiny-lm-a{act_bits}",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        pattern_period=("g",),
        ffn_type="gelu",
        quant=QuantConfig(act_bits=act_bits, attn_act_bits=act_bits),
        max_seq=512,
    )


def train_one(act_bits: int, steps: int, seed: int = 0) -> float:
    cfg = build_cfg(act_bits)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    tcfg = TL.TrainConfig(
        optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)
    )
    step = TL.make_train_step(
        cfg, tcfg, mesh, {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    )
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=seed))
    params, opt = TL.init_train_state(jax.random.PRNGKey(seed), cfg)
    last = float("nan")
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        params, opt, m = step(params, opt, batch)
        last = float(m["loss"])
    return last


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    wl = em.bert_base_qmm_workload()
    oh = em.BENCHMARK_OVERHEADS["BiT"]
    print(f"{'mode':6s} {'final_loss':>10s} {'GOPS':>8s} {'GOPS/W':>8s}")
    results = []
    for name in ("W1A8", "W1A4", "W1A2", "W1A1"):
        mode = MODES[name]
        loss = train_one(mode.act_bits, args.steps)
        gops, _ = em.throughput_gops(wl, mode, em.ZCU102_BETA, oh)
        eff = em.energy_efficiency(wl, mode, em.ZCU102_BETA, oh)
        results.append((name, loss, gops, eff))
        print(f"{name:6s} {loss:10.4f} {gops:8.1f} {eff:8.1f}")
    losses = [r[1] for r in results]
    effs = [r[3] for r in results]
    print(
        "[tradeoff] efficiency rises monotonically:",
        all(effs[i] < effs[i + 1] for i in range(len(effs) - 1)),
    )
    print(
        "[tradeoff] accuracy (lower loss) degrades toward W1A1:",
        losses[-1] >= min(losses),
    )


if __name__ == "__main__":
    main()
