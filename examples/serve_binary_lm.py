"""End-to-end serving driver: batched requests against a binary Transformer.

    PYTHONPATH=src python examples/serve_binary_lm.py

The accelerator's role (BETA is an inference engine): take a trained(-init)
model, run the OFFLINE weight pipeline (sign-binarize -> bit-pack 32/word ->
fold colsum corrections, the paper's 'performed offline' coefficients), then
serve a queue of batched requests through slot-based continuous batching on
the integer QMM datapath with a quantized KV cache.
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.smoke import smoke_variant
from repro.models import model_zoo as Z
from repro.runtime.serve_loop import Request, ServeEngine


def main() -> None:
    cfg = smoke_variant(get_config("granite-8b"))
    print(f"[serve] arch {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"mode {cfg.quant.mode_name}, int8 KV cache")

    params = Z.init_params(jax.random.PRNGKey(0), cfg)
    serving = Z.prepare_serving_params(params, cfg)

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    print(f"[serve] weight pipeline: {nbytes(params)/1e6:.1f} MB latent fp32 "
          f"-> {nbytes(serving)/1e6:.1f} MB packed serving")

    rng = np.random.default_rng(1)
    requests = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(rng.integers(4, 12),)).astype(np.int32),
            max_new_tokens=12,
            temperature=0.8 if i % 2 else 0.0,
        )
        for i in range(10)
    ]
    engine = ServeEngine(cfg, serving, batch_slots=4, max_len=64)
    t0 = time.perf_counter()
    done = engine.run(requests)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests -> {tokens} tokens in {dt:.1f}s")
    for i, r in enumerate(done[:5]):
        mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"  req{i} ({mode}): {r.output}")
    assert all(r.output and len(r.output) == r.max_new_tokens for r in done)
    print("[serve] all requests completed")


if __name__ == "__main__":
    main()
