"""End-to-end QAT driver: train a ~100M-param binary LM for a few hundred
steps and watch the loss drop (the paper's benchmark models are produced by
exactly this recipe: latent fp32 weights, STE binarization, quantized
activations).

    PYTHONPATH=src python examples/train_binary_lm.py [--steps 200]

~100M params: 8 layers x d_model 512 x ffn 2048, vocab 32000 (llama-style
dense blocks, W1A8) — batch sized for this CPU container; on a real pod the
same TrainConfig/pjit step scales out (see launch/train.py --mesh).
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, QuantConfig
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.runtime import fault_tolerance as FT
from repro.runtime import train_loop as TL


def build_cfg(d_model=512, layers=8, vocab=32000) -> ArchConfig:
    return ArchConfig(
        name="binary-lm-100m",
        family="dense",
        n_layers=layers,
        d_model=d_model,
        n_heads=8,
        n_kv_heads=4,
        d_ff=4 * d_model,
        vocab_size=vocab,
        pattern_period=("g",),
        ffn_type="silu_glu",
        quant=QuantConfig(act_bits=8, attn_act_bits=8),
        max_seq=2048,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-binary-lm")
    args = ap.parse_args()

    cfg = build_cfg()
    n_params = cfg.param_count()
    print(f"[example] binary LM: {n_params/1e6:.1f}M params, mode {cfg.quant.mode_name}")

    mesh = jax.sharding.Mesh(
        __import__("numpy").array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )
    tcfg = TL.TrainConfig(
        optimizer=adamw.AdamWConfig(
            lr=args.lr, warmup_steps=20, total_steps=args.steps
        )
    )
    step = TL.make_train_step(
        cfg, tcfg, mesh, {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    )
    pipe = TokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    )
    params, opt = TL.init_train_state(jax.random.PRNGKey(0), cfg)
    runner = FT.TrainingRunner(
        step, pipe, CheckpointManager(args.ckpt_dir, keep=2),
        FT.RunnerConfig(
            total_steps=args.steps, checkpoint_every=max(args.steps // 2, 1),
            log_every=max(args.steps // 10, 1),
        ),
    )
    runner.install_signal_handlers()
    start, params, opt = runner.try_restore(params, opt)
    t0 = time.time()
    params, opt, hist = runner.run(params, opt, start)
    if hist:
        print(
            f"[example] QAT loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
            f"in {time.time()-t0:.0f}s "
            f"({'DECREASED' if hist[-1]['loss'] < hist[0]['loss'] else 'did not decrease'})"
        )


if __name__ == "__main__":
    main()
